"""The dynamic (Ray-style) scheduler that Syndeo hosts *inside* the static
gang allocation -- the paper's scheduler-inside-a-scheduler.

Event-driven state machine, independent of the time source: the local
backend drives it with threads + wall clock, the simulation backend drives
it with a virtual clock (same code paths -- the paper-table benchmarks
exercise exactly this logic).

Features:
  * dependency-driven dispatch (tasks start when data + resource deps met),
  * locality-aware placement (prefer workers already holding the deps),
  * straggler mitigation: speculative re-execution past a runtime quantile,
  * retry with lineage reconstruction of lost objects on worker failure,
  * placement groups (STRICT_SPREAD / PACK) for gang-scheduled jobs,
  * multi-tenant fair share: per-tenant ready queues with a weighted
    dominant-share (DRF) picker layered on the WorkerIndex fast path --
    many principals contend for one gang allocation without starving each
    other (single-tenant clusters take the identical seed FIFO path),
  * graceful retirement: a DRAINING lifecycle state (begin_drain /
    drain_complete / finish_drain) that stops new placements, lets running
    tasks finish (or preempts them past a deadline), and migrates the
    node's solely-held hot objects to survivors before release -- so a
    drained worker, unlike a dropped one, never costs lineage recompute.
"""
from __future__ import annotations

import heapq
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.metrics import MetricsRegistry
from repro.core.object_store import (GlobalObjectStore, NodeStore, ObjectRef,
                                     shard_key)
from repro.core.security import SecurityError
from repro.core.task_graph import Task, TaskGraph, TaskSpec, TaskState

_SIG_UNSET = object()   # "compute the signature yourself" for _try_launch


@dataclass
class WorkerInfo:
    id: str
    resources: Dict[str, float]
    available: Dict[str, float] = field(default_factory=dict)
    alive: bool = True
    last_heartbeat: float = 0.0
    running: set = field(default_factory=set)
    draining: bool = False       # retiring: no new placements, tasks drain
    actors: set = field(default_factory=set)  # live service-actor ids hosted

    def __post_init__(self):
        if not self.available:
            self.available = dict(self.resources)

    @property
    def load(self) -> float:
        return sum(self.resources.values()) - sum(self.available.values())

    @property
    def idle(self) -> bool:
        # a replica actor between request bursts holds its resources but
        # runs no task: the worker is NOT idle -- idle-exit and idle
        # scale-down must never reap a serving replica (see ISSUE 9)
        return not self.running and not self.actors and all(
            self.available.get(k, 0.0) >= v for k, v in self.resources.items())

    def fits(self, req: Dict[str, float]) -> bool:
        return all(self.available.get(k, 0.0) >= v for k, v in req.items())

    def acquire(self, req: Dict[str, float]):
        for k, v in req.items():
            self.available[k] = self.available.get(k, 0.0) - v

    def release(self, req: Dict[str, float]):
        for k, v in req.items():
            self.available[k] = self.available.get(k, 0.0) + v


@dataclass
class SchedulerConfig:
    speculation_factor: float = 2.0      # speculate past factor x group median
    speculation_min_samples: int = 5
    heartbeat_timeout: float = 10.0
    locality_weight: float = 1.0         # bytes-on-node score weight
    enable_speculation: bool = True
    placement_mode: str = "indexed"      # "indexed" (heap) or "linear" (scan)
    # "fair": per-tenant ready queues, weighted dominant-share (DRF) picker;
    # "fifo": the seed's single global arrival-order queue (the benchmark
    # baseline). With one tenant both are identical, so the default path is
    # zero-cost for single-tenant clusters.
    dispatch_policy: str = "fair"
    # two-phase drain moves: a dispatched migration that has not been
    # acknowledged within this window is aborted (probe-first: a push
    # whose ack was lost is promoted to a commit) and re-planned.
    migration_timeout_s: float = 10.0
    # control-plane sharding: >1 partitions the ready queues by tenant
    # hash and switches schedule() to incremental READY tracking (no
    # full-graph scan per event). 1 = the seed-equivalent baseline; the
    # cluster backends also size the object store's directory shards
    # from this value.
    shards: int = 1


@dataclass
class TenantState:
    """Fair-share bookkeeping for one tenant (see Scheduler.register_tenant)."""
    tenant_id: str
    weight: float = 1.0
    usage: Dict[str, float] = field(default_factory=dict)  # allocated now
    launched: int = 0
    finished: int = 0


class RateLimitExceeded(SecurityError):
    """A tenant submitted tasks faster than its admitted rate (quotas
    bound *state*, rate limits bound *churn*)."""


@dataclass
class TokenBucket:
    """Classic token bucket: `rate_per_s` sustained, `burst` peak."""
    rate_per_s: float
    burst: float
    tokens: float = 0.0
    last: Optional[float] = None

    def __post_init__(self):
        self.tokens = self.burst

    def try_take(self, now: float) -> bool:
        if self.last is not None:
            self.tokens = min(self.burst, self.tokens
                              + max(0.0, now - self.last) * self.rate_per_s)
        self.last = now
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return True
        return False


@dataclass
class DrainState:
    """Bookkeeping for one DRAINING worker (see Scheduler.begin_drain)."""
    worker_id: str
    started_at: float
    deadline_at: Optional[float] = None   # absolute; None = wait forever
    pending: set = field(default_factory=set)   # object ids mid-migration
    moved: set = field(default_factory=set)     # object ids settled
    planned: int = 0                            # migrations dispatched
    # bandwidth-aware planner state: bytes of in-flight moves per
    # destination (released as they land/fail), and where each pending
    # object was sent -- capacity/link projections read these
    assigned_bytes: Dict[str, int] = field(default_factory=dict)
    inflight_to: Dict[str, Tuple[str, int]] = field(default_factory=dict)
    # when each pending move was dispatched -- the migration-timeout
    # sweep aborts (probe-first) and re-plans moves that never acked
    dispatched_at: Dict[str, float] = field(default_factory=dict)

    def _unassign(self, object_id: str):
        self.dispatched_at.pop(object_id, None)
        dst_size = self.inflight_to.pop(object_id, None)
        if dst_size is not None:
            dst, size = dst_size
            left = self.assigned_bytes.get(dst, 0) - size
            if left > 0:
                self.assigned_bytes[dst] = left
            else:
                self.assigned_bytes.pop(dst, None)


@dataclass
class ActorInfo:
    """One live service actor (a long-running replica hosted by a worker).

    Unlike a task, an actor holds its resources for its whole lifetime and
    is never rescheduled by the task graph: death is surfaced to the
    serving layer (router / autoscaler), which decides whether to respawn.
    """
    actor_id: str
    worker_id: str
    resources: Dict[str, float]
    tenant_id: str = "default"
    placement_group: Optional[str] = None
    created_at: float = 0.0


class _ReadyQueue:
    """One tenant's ready queue inside a shard (cfg.shards > 1).

    Entries are (submitted_at, seq, task_id, sig) kept in sorted order:
    normal submits arrive already ordered (submitted_at and seq are both
    monotonic), so a push is a plain append; an out-of-order insert (a
    retry, preempt, or reconstruction re-queues a task with an old
    submitted_at) just flips `dirty` and the next pass sorts once.

    `sigs` counts the resource signatures present (None = placement-group
    task, always examined), so a dispatch pass can prove in O(distinct
    signatures) that nothing in the queue can place -- every signature it
    holds already failed this pass -- and skip the scan entirely. A
    blocked thousand-task backlog then costs ~nothing per scheduling
    event, which is where the seed's per-event full rescan burned."""

    __slots__ = ("entries", "dirty", "sigs")

    def __init__(self):
        self.entries: List[Tuple[float, int, str, Any]] = []
        self.dirty = False
        self.sigs: Dict[Any, int] = {}

    def enqueue(self, entry: Tuple[float, int, str, Any]):
        if self.entries and entry < self.entries[-1]:
            self.dirty = True
        self.entries.append(entry)
        sig = entry[3]
        self.sigs[sig] = self.sigs.get(sig, 0) + 1

    def sorted_entries(self) -> List[Tuple[float, int, str, Any]]:
        if self.dirty:
            self.entries.sort()
            self.dirty = False
        return self.entries

    def remove_at(self, i: int):
        sig = self.entries[i][3]
        del self.entries[i]
        n = self.sigs.get(sig, 0) - 1
        if n > 0:
            self.sigs[sig] = n
        else:
            self.sigs.pop(sig, None)

    def rebuild(self, entries: List[Tuple[float, int, str, Any]]):
        """Replace the contents wholesale (entries must already be sorted)."""
        self.entries = entries
        self.dirty = False
        sigs: Dict[Any, int] = {}
        for e in entries:
            sigs[e[3]] = sigs.get(e[3], 0) + 1
        self.sigs = sigs

    def all_infeasible(self, infeasible: set) -> bool:
        """True iff every task still queued carries a resource signature
        that already failed this pass (sound because availability only
        shrinks within a pass). Placement-group entries (sig None) always
        force a scan -- their feasibility is per-bundle, not per-sig."""
        return all(s is not None and s in infeasible for s in self.sigs)


class WorkerIndex:
    """Resource-feasibility index: one lazy min-heap per resource key,
    ordered by (load, registration seq), so placement is ~O(log n) in the
    worker count instead of a per-task linear scan.

    Entries are invalidated lazily: every load change pushes a fresh entry
    and stale ones are discarded at pop time (an entry is valid iff its load
    matches the worker's current load). The (load, seq) ordering reproduces
    the linear scan's selection exactly: least-loaded feasible worker,
    first-registered wins ties.
    """

    _COMPACT_FACTOR = 4  # rebuild a heap once stale entries dominate

    def __init__(self):
        self._heaps: Dict[str, List[Tuple[float, int, str]]] = {}
        self._members: Dict[str, set] = {}       # resource key -> worker ids
        self._workers: Dict[str, WorkerInfo] = {}
        self._seq: Dict[str, int] = {}
        self._next_seq = 0
        # cluster-wide free capacity per resource key, maintained from the
        # per-worker snapshots below on every touch(): when the sum cannot
        # cover a request, no single worker can either, so a hopeless
        # pick() fails in O(1) instead of draining the whole heap proving
        # it (the dominant head cost on a saturated cluster)
        self._avail: Dict[str, Dict[str, float]] = {}
        self._avail_totals: Dict[str, float] = {}

    def __len__(self) -> int:
        return len(self._workers)

    def _keys_of(self, w: WorkerInfo) -> List[str]:
        return list(w.resources.keys()) + [""]   # "" = the all-workers heap

    def add(self, w: WorkerInfo):
        self._workers[w.id] = w
        self._seq[w.id] = self._next_seq
        self._next_seq += 1
        for k in self._keys_of(w):
            self._members.setdefault(k, set()).add(w.id)
        self.touch(w)

    def remove(self, worker_id: str):
        w = self._workers.pop(worker_id, None)
        if w is None:
            return
        self._seq.pop(worker_id, None)
        for k, v in self._avail.pop(worker_id, {}).items():
            self._avail_totals[k] = self._avail_totals.get(k, 0.0) - v
        for k in self._keys_of(w):
            self._members.get(k, set()).discard(worker_id)

    def _note_avail(self, w: WorkerInfo):
        old = self._avail.get(w.id)
        tot = self._avail_totals
        if old is None:
            for k, v in w.available.items():
                tot[k] = tot.get(k, 0.0) + v
        else:
            for k in old.keys() | w.available.keys():
                delta = w.available.get(k, 0.0) - old.get(k, 0.0)
                if delta:
                    tot[k] = tot.get(k, 0.0) + delta
        self._avail[w.id] = dict(w.available)

    def touch(self, w: WorkerInfo):
        """Re-index after a load change (acquire/release)."""
        if w.id not in self._workers:
            return
        self._note_avail(w)
        entry = (w.load, self._seq[w.id], w.id)
        for k in self._keys_of(w):
            heap = self._heaps.setdefault(k, [])
            heapq.heappush(heap, entry)
            if len(heap) > self._COMPACT_FACTOR * max(len(self._members[k]), 1):
                self._compact(k)

    def _compact(self, key: str):
        fresh = [(w.load, self._seq[wid], wid)
                 for wid in self._members.get(key, ())
                 if (w := self._workers.get(wid)) is not None and w.alive
                 and not w.draining]
        heapq.heapify(fresh)
        self._heaps[key] = fresh

    def seq_of(self, worker_id: str) -> int:
        """Registration order (join sequence); -1 for unknown workers."""
        return self._seq.get(worker_id, -1)

    def pick(self, req: Dict[str, float]) -> Optional[WorkerInfo]:
        """Least-loaded alive, non-draining worker that fits `req` (ties:
        registration order). Returns None when nothing fits. DRAINING
        workers are evicted lazily at pop time -- their entries are simply
        discarded, and a cancelled drain re-surfaces via touch() with the
        original registration seq intact."""
        needed = [k for k, v in req.items() if v > 0]
        for k in needed:
            if not self._members.get(k):
                return None                  # required resource nowhere present
            if self._avail_totals.get(k, 0.0) + 1e-9 < req[k]:
                # cluster-wide free capacity cannot cover the request, so
                # no single worker can: fail without draining the heap.
                # The totals may overcount (draining workers stay counted
                # until touched), which only weakens the filter -- a pass
                # through it still ends in the exact heap scan below.
                return None
        key = min(needed, key=lambda k: len(self._members[k])) if needed else ""
        heap = self._heaps.get(key, [])
        popped: List[Tuple[float, int, str]] = []
        seen: set = set()
        best: Optional[WorkerInfo] = None
        while heap:
            load, seq, wid = heapq.heappop(heap)
            w = self._workers.get(wid)
            if (w is None or not w.alive or w.draining or wid in seen
                    or abs(w.load - load) > 1e-12):
                continue                     # stale, draining, or duplicate
            seen.add(wid)
            popped.append((load, seq, wid))
            if w.fits(req):
                best = w
                break
        for e in popped:                     # keep valid entries indexed
            heapq.heappush(heap, e)
        return best


class Scheduler:
    """Head-node scheduler. All mutation happens through the public event
    methods; `launch_fn(task, worker_id)` is injected by the backend."""

    def __init__(self, store: GlobalObjectStore,
                 launch_fn: Callable[[Task, str], None],
                 cancel_fn: Optional[Callable[[Task, str], None]] = None,
                 config: SchedulerConfig = SchedulerConfig(),
                 clock: Callable[[], float] = time.monotonic,
                 metrics: Optional[MetricsRegistry] = None):
        self.store = store
        # observability plane: sojourn histograms (and, on the threaded
        # head, worker-folded histograms via the shared MetricsHub) live
        # here -- one registry per control plane
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.graph = TaskGraph()
        self.workers: Dict[str, WorkerInfo] = {}
        self.launch_fn = launch_fn
        self.cancel_fn = cancel_fn or (lambda t, w: None)
        self.cfg = config
        self.clock = clock
        self.index = WorkerIndex()
        self._group_runtimes: Dict[str, List[float]] = {}
        self._placement_bindings: Dict[str, Dict[int, str]] = {}
        self._pending_groups: Dict[str, Tuple[List[Dict[str, float]], str]] = {}
        # drain pipeline: migrate_fn(worker_id, ref, dst) is injected by the
        # backend to execute one object move (sim adds transfer latency);
        # None executes synchronously through the store.
        self.migrate_fn: Optional[Callable[[str, ObjectRef, str], None]] = None
        self._drains: Dict[str, DrainState] = {}
        self.tenants: Dict[str, TenantState] = {}
        self._rate_limits: Dict[str, TokenBucket] = {}
        # placeable capacity (alive, non-draining) per resource key --
        # see _totals_add / _cluster_totals
        self._totals: Dict[str, float] = {}
        # sharded dispatch state (cfg.shards > 1): per-shard ready queues
        # keyed by tenant -- persistent sorted lists of (submitted_at,
        # seq, id, sig) where seq is graph-insertion order, so a scan
        # walks exactly the seed's stable sorted(ready, key=submitted_at)
        # order without rebuilding anything per event
        n_shards = max(1, config.shards)
        self._ready_shards: List[Dict[str, _ReadyQueue]] = [
            {} for _ in range(n_shards)]
        self._queued: List[set] = [set() for _ in range(n_shards)]
        self._task_seq: Dict[str, int] = {}
        self._next_task_seq = 0
        # speculation reverse map (original id -> twin id): makes the
        # twin-cancel lookup on finish O(1) instead of a full-graph scan
        self._twin_of: Dict[str, str] = {}
        # service-actor registry (the serving plane): actor id -> ActorInfo
        self.actors: Dict[str, ActorInfo] = {}
        self.stats = {"launched": 0, "finished": 0, "failed": 0, "retried": 0,
                      "speculative": 0, "reconstructed": 0, "cancelled": 0,
                      "drained": 0, "migrated_objects": 0, "preempted": 0,
                      "migration_denied": 0, "rate_limited": 0,
                      "actors_created": 0, "actors_exited": 0,
                      "actors_lost": 0}

    # -- tenancy ---------------------------------------------------------------

    def register_tenant(self, tenant_id: str,
                        weight: float = 1.0) -> TenantState:
        """Register (or re-weight) a tenant for fair-share dispatch. Unknown
        tenants auto-register at weight 1.0 on first submit."""
        ts = self.tenants.get(tenant_id)
        if ts is None:
            ts = self.tenants[tenant_id] = TenantState(tenant_id, weight)
        else:
            ts.weight = weight
        return ts

    def _tenant_state(self, tenant_id: str) -> TenantState:
        ts = self.tenants.get(tenant_id)
        return ts if ts is not None else self.register_tenant(tenant_id)

    def set_submit_rate(self, tenant_id: str, rate_per_s: float,
                        burst: Optional[float] = None):
        """Token-bucket submit rate limit for one tenant: `rate_per_s`
        sustained submissions with bursts up to `burst` (default: one
        second's worth, at least 1). Quotas bound a tenant's live *state*;
        this bounds its *churn* -- a submit loop cannot monopolize the
        head's admission path. Pass rate_per_s <= 0 to remove the limit."""
        if rate_per_s <= 0:
            self._rate_limits.pop(tenant_id, None)
            return
        burst = max(1.0, rate_per_s if burst is None else burst)
        self._rate_limits[tenant_id] = TokenBucket(rate_per_s, burst)

    def _totals_add(self, w: WorkerInfo, sgn: float):
        """Maintain the placeable-capacity cache: called with +1 when a
        worker becomes placeable (join, drain cancelled) and -1 when it
        stops being placeable (drain begun, failed, removed)."""
        for k, v in w.resources.items():
            self._totals[k] = self._totals.get(k, 0.0) + sgn * v

    def _cluster_totals(self) -> Dict[str, float]:
        """Total resources across alive, non-draining workers. Kept
        incrementally (the fair pass reads this per scheduling event --
        recomputing it was an O(workers) scan on the hot path). Callers
        treat the returned dict as read-only."""
        return self._totals

    def _dominant_share(self, ts: TenantState,
                        totals: Dict[str, float]) -> float:
        """Weighted dominant share (DRF): the tenant's largest fraction of
        any one cluster resource, divided by its weight."""
        share = 0.0
        for k, used in ts.usage.items():
            total = totals.get(k, 0.0)
            if total > 0:
                share = max(share, used / total)
        return share / max(ts.weight, 1e-9)

    def tenant_shares(self) -> Dict[str, float]:
        """Weighted dominant share per registered tenant (fairness metric:
        equal-weight tenants under contention should see equal values)."""
        totals = self._cluster_totals()
        return {tid: self._dominant_share(ts, totals)
                for tid, ts in self.tenants.items()}

    def backlog_by_tenant(self) -> Dict[str, int]:
        """READY+PENDING demand per tenant (autoscaler attribution)."""
        out: Dict[str, int] = {}
        for t in self.graph.tasks.values():
            if t.state in (TaskState.READY, TaskState.PENDING):
                tid = t.spec.tenant_id
                out[tid] = out.get(tid, 0) + 1
        return out

    def _usage_add(self, tenant_id: str, req: Dict[str, float], sgn: float):
        usage = self._tenant_state(tenant_id).usage
        for k, v in req.items():
            usage[k] = usage.get(k, 0.0) + sgn * v

    # -- membership ----------------------------------------------------------

    def add_worker(self, worker: WorkerInfo):
        worker.last_heartbeat = self.clock()
        old = self.workers.get(worker.id)
        if old is not None and old.alive and not old.draining:
            self._totals_add(old, -1.0)      # re-join replaces, not stacks
        self.workers[worker.id] = worker
        if worker.alive and not worker.draining:
            self._totals_add(worker, +1.0)
        self.index.add(worker)
        self._retry_pending_groups()
        self.schedule()

    def remove_worker(self, worker_id: str):
        self.on_worker_failed(worker_id, reason="removed")

    def retire_worker(self, worker_id: str) -> bool:
        """Graceful scale-down: remove an *idle* worker without the failure
        path (no task requeue, no lineage churn for running work). Returns
        False if the worker is busy, hosts a live service actor, or is
        bound to a placement group."""
        w = self.workers.get(worker_id)
        if w is None or w.running or w.actors:
            return False
        if any(worker_id in binding.values()
               for binding in self._placement_bindings.values()):
            return False
        self._remove_node(worker_id)
        return True

    # -- service actors ------------------------------------------------------
    #
    # place_actor(a)   : pick a worker, acquire resources for the actor's
    #                    LIFETIME (not one task), register it
    # remove_actor(a)  : graceful exit -- release resources, forget
    # Actor-hosting workers refuse retire_worker and the idle-exit `leave`
    # handshake, and a drain of their node is only complete once every
    # hosted replica has exited (handoff before release).

    def place_actor(self, actor_id: str, resources: Dict[str, float],
                    tenant_id: str = "default",
                    placement_group: Optional[str] = None,
                    bundle_index: Optional[int] = None) -> Optional[str]:
        """Place a long-running service actor; returns the hosting worker
        id or None when nothing fits. Placement-group bundles pin the
        actor to the bundle's bound worker (gang-placed replicas); free
        placement packs by least load with deterministic id tiebreak."""
        if actor_id in self.actors:
            raise ValueError(f"actor {actor_id!r} already placed")
        w: Optional[WorkerInfo] = None
        if placement_group is not None:
            binding = self._placement_bindings.get(placement_group)
            if binding is None:
                return None
            bound = binding.get(bundle_index if bundle_index is not None
                                else 0)
            cand = self.workers.get(bound or "")
            if (cand is not None and cand.alive and not cand.draining
                    and cand.fits(resources)):
                w = cand
        else:
            fits = [c for c in self.workers.values()
                    if c.alive and not c.draining and c.fits(resources)]
            if fits:
                w = min(fits, key=lambda c: (c.load, c.id))
        if w is None:
            return None
        w.acquire(resources)
        w.actors.add(actor_id)
        self._usage_add(tenant_id, resources, +1.0)
        self.actors[actor_id] = ActorInfo(
            actor_id, w.id, dict(resources), tenant_id,
            placement_group, created_at=self.clock())
        self.index.touch(w)
        self.stats["actors_created"] += 1
        return w.id

    def remove_actor(self, actor_id: str) -> bool:
        """Graceful actor exit (drained replica, scale-down): release the
        lifetime resource hold and forget the actor."""
        info = self.actors.pop(actor_id, None)
        if info is None:
            return False
        w = self.workers.get(info.worker_id)
        if w is not None:
            w.actors.discard(actor_id)
            w.release(info.resources)
            self.index.touch(w)
        self._usage_add(info.tenant_id, info.resources, -1.0)
        self.stats["actors_exited"] += 1
        self.schedule()
        return True

    def actors_on(self, worker_id: str) -> List[str]:
        w = self.workers.get(worker_id)
        return sorted(w.actors) if w is not None else []

    def _remove_node(self, worker_id: str):
        """Shared teardown for the drop (retire_worker) and drain
        (finish_drain) paths: unregister the node store, mark objects that
        lost their last copy, and forget the worker."""
        w = self.workers[worker_id]
        if w.alive and not w.draining:       # drained workers left at
            self._totals_add(w, -1.0)        # begin_drain already
        w.alive = False
        for oid in self.store.unregister_node(worker_id):
            self.graph.object_lost(oid)
        self.index.remove(worker_id)
        self._drains.pop(worker_id, None)
        for aid in sorted(w.actors):         # graceful paths exit first;
            info = self.actors.pop(aid, None)  # anything left is gone
            if info is not None:
                self._usage_add(info.tenant_id, info.resources, -1.0)
                self.stats["actors_lost"] += 1
        del self.workers[worker_id]

    # -- graceful drain (DRAINING lifecycle state) ---------------------------
    #
    # begin_drain(w)    : stop new placements, plan + dispatch migrations
    # check_drains()    : preempt running tasks past the drain deadline
    # drain_complete(w) : no running tasks, no in-flight migrations
    # finish_drain(w)   : unregister the node (loses nothing hot) + remove
    #
    # Unlike retire_worker (the drop path, kept for comparison), a drain is
    # allowed on a *busy* worker and never costs lineage recompute for hot
    # objects: every solely-held hot object is migrated to a survivor first.

    def begin_drain(self, worker_id: str,
                    deadline_s: Optional[float] = None) -> bool:
        """Move a worker into DRAINING. Returns False for unknown / dead /
        already-draining / placement-group-bound workers."""
        w = self.workers.get(worker_id)
        if w is None or not w.alive or w.draining:
            return False
        if any(worker_id in binding.values()
               for binding in self._placement_bindings.values()):
            return False
        w.draining = True            # lazily evicted from the WorkerIndex
        self._totals_add(w, -1.0)    # no longer placeable capacity
        now = self.clock()
        self._drains[worker_id] = DrainState(
            worker_id, now,
            deadline_at=None if deadline_s is None else now + deadline_s)
        self._dispatch_moves(worker_id)
        return True

    def cancel_drain(self, worker_id: str) -> bool:
        """Abort a drain (demand returned): the worker becomes placeable
        again. Already-migrated objects stay where they landed -- extra
        replicas are harmless."""
        w = self.workers.get(worker_id)
        if w is None or not w.draining:
            return False
        w.draining = False
        self._totals_add(w, +1.0)    # placeable again
        self._drains.pop(worker_id, None)
        self.index.touch(w)          # re-surface in the placement heaps
        self.schedule()
        return True

    def drain_status(self, worker_id: str) -> Optional[DrainState]:
        return self._drains.get(worker_id)

    def drain_deadline_s(self, worker_id: str) -> Optional[float]:
        """Seconds of drain budget left for `worker_id` (None = not
        draining, or draining without a deadline). Attached to each
        migrate directive so the source worker can serve its batched
        pushes deadline-soonest-first -- a preemption-notice drain races
        its eviction window. Never negative: a blown deadline reads as
        0.0 budget, the preemption sweep handles the rest."""
        st = self._drains.get(worker_id)
        if st is None or st.deadline_at is None:
            return None
        return max(0.0, st.deadline_at - self.clock())

    def draining_workers(self) -> List[str]:
        return list(self._drains)

    def worker_seq(self, worker_id: str) -> int:
        """Join order of a live worker (reverse-join release policies)."""
        return self.index.seq_of(worker_id)

    def _dispatch_moves(self, worker_id: str):
        """Plan + dispatch migrations for every at-risk hot object on the
        draining node. At-risk = no copy on a live, *non-draining* node:
        a holder that is itself draining is not a survivor (two draining
        nodes must not each count the other as cover and drop the last
        copies). Called again from drain_complete(): a running task that
        finishes *during* the drain may store fresh results on the node,
        and a holder that started draining since the last scan re-arms.

        Destination choice is **bandwidth-aware** (it used to round-robin):
        objects are packed largest-first onto the survivor whose link
        carries the least traffic -- cumulative data-plane bytes
        (store.link_load) plus this drain's own in-flight moves -- among
        survivors with store capacity left for the blob. A survivor whose
        free memory (minus in-flight assignments) cannot hold the blob is
        skipped, so a drain never evicts a destination's working set; when
        nothing fits, the head store is the fallback, then the emptiest
        survivor. Big fan-out drains therefore spread across idle NICs
        instead of convoying behind one hot destination."""
        st = self._drains.get(worker_id)
        if st is None:
            return
        objs = self.store.objects_on(worker_id)
        if not objs:
            return
        draining = set(self._drains)
        # hoisted per scan, not per object: the hot-dependency set (one
        # pass over tasks), the survivor list, and the capacity snapshot
        active = (TaskState.PENDING, TaskState.READY, TaskState.RUNNING)
        hot_deps = {d.id for t in self.graph.tasks.values()
                    if t.state in active for d in t.deps}
        cands = sorted(
            (w.id for w in self.workers.values()
             if w.alive and not w.draining and w.id != worker_id
             and self.store.has_node(w.id)),
            key=lambda wid: self.index.seq_of(wid))
        head_ok = self.store.has_node("head")
        free: Dict[str, Optional[int]] = {
            c: self.store.node_free_bytes(c) for c in cands}
        if head_ok:
            free["head"] = self.store.node_free_bytes("head")
        # net the snapshot of EVERY drain's in-flight moves: concurrent
        # drains must not jointly overbook one survivor, and this drain's
        # own pending moves from earlier scans are not yet in used_bytes
        inflight: Dict[str, int] = {}
        for st2 in self._drains.values():
            for c, b in st2.assigned_bytes.items():
                inflight[c] = inflight.get(c, 0) + b
                if free.get(c) is not None:
                    free[c] -= b
        # bytes newly committed to each destination *within this scan* --
        # stays charged even after a synchronous move lands (the `free`
        # snapshot predates the landing, so the charge must not vanish
        # with the in-flight assignment)
        planned_now: Dict[str, int] = {}
        # quota-aware destinations: per-(tenant, node) live bytes, read
        # lazily from the store and charged as this scan plans (a landed
        # sync move may be charged twice -- over-counting only tightens
        # the cap, never breaches it)
        tenant_caps: Dict[str, Optional[int]] = {}
        tenant_on: Dict[Tuple[str, str], int] = {}
        # largest blobs plan first: they have the fewest feasible
        # destinations, and spreading them dominates drain latency
        for oid, ref in sorted(objs.items(), key=lambda kv: -kv[1].size):
            if oid in st.pending or oid in st.moved:
                continue
            covered = any(n != worker_id and n not in draining
                          and self.store.has_node(n)
                          for n in self.store.locations(ref))
            if covered:
                continue   # not memoized: cover is re-checked every scan
            if self.store.refcount(oid) <= 0 and oid not in hot_deps:
                st.moved.add(oid)    # cold: dropping it costs nothing
                continue
            if self.store.move_in_flight(oid) is not None:
                if any(oid in st2.pending for st2 in self._drains.values()):
                    # ANOTHER drain's move of this co-held object is in
                    # flight: its landing covers this drain too --
                    # aborting it here would ping-pong two drains into
                    # killing each other's transfers forever
                    continue
                # an in-flight store move no drain tracks anymore (its
                # dispatch failed, or its COMMIT was dropped): resolve it
                # before re-planning -- the probe promotes a landed push
                # to a COMMIT, anything else is aborted so a fresh
                # begin_move can succeed
                if self.store.abort_move(oid, probe=True):
                    st.moved.add(oid)
                    self.stats["migrated_objects"] += 1
                    continue
            dst = self._plan_destination(st, ref, cands, free, head_ok,
                                         planned_now, inflight,
                                         tenant_caps, tenant_on)
            if dst is None:
                st.moved.add(oid)    # no survivor: degrade to drop+lineage
                continue
            st.pending.add(oid)
            st.planned += 1
            planned_now[dst] = planned_now.get(dst, 0) + ref.size
            if (ref.tenant, dst) in tenant_on:
                tenant_on[(ref.tenant, dst)] += ref.size
            st.assigned_bytes[dst] = st.assigned_bytes.get(dst, 0) + ref.size
            st.inflight_to[oid] = (dst, ref.size)
            st.dispatched_at[oid] = self.clock()
            if self.migrate_fn is not None:
                self.migrate_fn(worker_id, ref, dst)
            else:
                try:
                    moved = self.store.migrate(ref, worker_id, dst)
                except SecurityError:
                    # a tenant-scoped migration guard cannot move another
                    # tenant's object: unmovable, degrade to drop + lineage
                    self.note_migration_denied(worker_id, ref)
                    continue
                if moved:
                    self.note_migrated(worker_id, ref)
                else:
                    # destination vanished mid-call: re-plan on the next scan
                    self.note_migration_failed(worker_id, ref)

    def _plan_destination(self, st: DrainState, ref: ObjectRef,
                          cands: List[str], free: Dict[str, Optional[int]],
                          head_ok: bool, planned_now: Dict[str, int],
                          inflight: Dict[str, int],
                          tenant_caps: Dict[str, Optional[int]],
                          tenant_on: Dict[Tuple[str, str], int]
                          ) -> Optional[str]:
        """One placement decision of the bandwidth-aware drain planner:
        least-loaded link among capacity-feasible survivors where the
        owning tenant's per-node quota is not breached; head fallback;
        else the emptiest survivor (least-bad overflow). `free` is already
        net of every drain's in-flight moves; `planned_now` charges this
        scan's own commitments (landed or not) on top; `inflight` is the
        scan-start snapshot of all drains' pending bytes per destination
        (precomputed once -- a per-object re-sum over every DrainState
        would make large drains quadratic on the head)."""
        def projected_link(c: str) -> int:
            # link_load counts landed transfers, inflight + planned_now
            # the committed ones; a this-scan synchronous landing appears
            # in both link_load and planned_now -- the slight double
            # charge only strengthens the spreading pressure
            return self.store.link_load(c) + inflight.get(c, 0) \
                + planned_now.get(c, 0)

        def fits(c: str) -> bool:
            f = free.get(c)
            return f is None or f - planned_now.get(c, 0) >= ref.size

        if ref.tenant not in tenant_caps:
            quota = self.store.quota_of(ref.tenant)
            tenant_caps[ref.tenant] = getattr(quota, "max_bytes_per_node",
                                              None) if quota else None
        cap = tenant_caps[ref.tenant]

        def tenant_fits(c: str) -> bool:
            # quota-aware destination: skip survivors where the move would
            # breach the owning tenant's per-node cap (the tenant is
            # already memory-rich there); the head fallback and the
            # last-resort overflow stay exempt -- an operator escape hatch
            # beats dropping the last copy
            if cap is None:
                return True
            key = (ref.tenant, c)
            if key not in tenant_on:
                tenant_on[key] = self.store.tenant_bytes_on(c, ref.tenant)
            return tenant_on[key] + ref.size <= cap

        feasible = [c for c in cands if fits(c) and tenant_fits(c)]
        if feasible:
            return min(feasible,
                       key=lambda c: (projected_link(c),
                                      self.index.seq_of(c)))
        if head_ok and fits("head"):
            return "head"
        if cands:      # everything over capacity: emptiest survivor wins
            return max(cands,
                       key=lambda c: ((free.get(c) if free.get(c) is not None
                                       else float("inf"))
                                      - planned_now.get(c, 0)))
        return "head" if head_ok else None

    def note_move_dispatched(self, worker_id: str, object_id: str):
        """Restart a pending move's timeout clock: called when the bytes
        actually start moving (the source worker picked the directive up,
        or the head fell back to a relay copy) -- a slow poll or a long
        relay transfer must not be aborted against a window that started
        at *plan* time."""
        st = self._drains.get(worker_id)
        if st is not None and object_id in st.dispatched_at:
            st.dispatched_at[object_id] = self.clock()

    def note_migrated(self, worker_id: str, ref: ObjectRef):
        """One migration landed (called by the backend's migrate executor)."""
        st = self._drains.get(worker_id)
        if st is None:
            return
        if ref.id in st.pending:
            st.pending.discard(ref.id)
            st._unassign(ref.id)
            st.moved.add(ref.id)
            self.stats["migrated_objects"] += 1

    def note_migration_failed(self, worker_id: str, ref: ObjectRef):
        """A dispatched move could not land (e.g. its destination died):
        put the object back on the planning table -- the next
        drain_complete() scan re-plans it toward a live survivor."""
        st = self._drains.get(worker_id)
        if st is None:
            return
        st.pending.discard(ref.id)
        st._unassign(ref.id)

    def note_migration_denied(self, worker_id: str, ref: ObjectRef):
        """The migration guard refused the move (cross-tenant): the object
        is unmovable under the installed guard, so the drain degrades to
        the drop path for it -- lineage will rebuild it if anyone asks."""
        st = self._drains.get(worker_id)
        if st is None:
            return
        st.pending.discard(ref.id)
        st._unassign(ref.id)
        st.moved.add(ref.id)
        self.stats["migration_denied"] += 1

    def check_drains(self, now: Optional[float] = None):
        """Deadline enforcement: preempt (requeue) tasks still running on a
        draining worker past its deadline. Preemption is not a failure --
        it does not count against max_retries. Also sweeps dispatched
        migrations that never acknowledged within migration_timeout_s:
        each is aborted probe-first (a push whose ack was lost is promoted
        to a COMMIT) and the drain re-plans the rest."""
        now = self.clock() if now is None else now
        self._check_move_timeouts(now)
        preempted = False
        for wid, st in list(self._drains.items()):
            w = self.workers.get(wid)
            if w is None or st.deadline_at is None or now < st.deadline_at:
                continue
            for tid in list(w.running):
                task = self.graph.tasks[tid]
                self.cancel_fn(task, wid)
                self._release(task)
                task.state = TaskState.READY if self._deps_live(task) \
                    else TaskState.PENDING
                if task.state == TaskState.PENDING:
                    self.graph.rewait(task)
                task.worker = None
                # preemption is the cluster's choice, not the task's fault:
                # give back the attempt that schedule() will re-charge
                task.attempts = max(0, task.attempts - 1)
                self._enqueue_ready(task)
                self.stats["preempted"] += 1
                preempted = True
        if preempted:
            self.schedule()

    def _check_move_timeouts(self, now: float):
        """Abort-and-re-plan sweep for two-phase moves stuck in flight:
        a source that crashed mid-push, a destination that died pre-ack,
        or a dropped COMMIT all look the same from here -- no ack. The
        store-side abort probes the destination first, so the
        dropped-commit case converges to a COMMIT, not a re-copy."""
        timeout = self.cfg.migration_timeout_s
        for wid, st in list(self._drains.items()):
            expired = [oid for oid, t0 in st.dispatched_at.items()
                       if now - t0 >= timeout]
            replan = False
            for oid in expired:
                ref = ObjectRef(oid)
                if self.store.abort_move(oid, probe=True):
                    self.note_migrated(wid, ref)     # push landed; only
                else:                                # the ack was lost
                    self.note_migration_failed(wid, ref)
                    replan = True
            if replan:
                self._dispatch_moves(wid)

    def drain_complete(self, worker_id: str) -> bool:
        """True once the worker has no running tasks, every hosted service
        actor has exited (replica handoff before release), and every
        planned migration has landed (re-scans for results produced
        mid-drain)."""
        w = self.workers.get(worker_id)
        st = self._drains.get(worker_id)
        if w is None or st is None:
            return False
        if w.running or w.actors:
            return False
        self._dispatch_moves(worker_id)      # pick up late-arriving objects
        return not st.pending

    def finish_drain(self, worker_id: str) -> bool:
        """Release a fully drained worker. Nothing hot is lost: migrations
        already moved every solely-held hot object, so unregistering the
        node only drops redundant or cold copies."""
        if not self.drain_complete(worker_id):
            return False
        self._remove_node(worker_id)         # loses cold/covered copies only
        self.stats["drained"] += 1
        self.schedule()
        return True

    def heartbeat(self, worker_id: str):
        w = self.workers.get(worker_id)
        if w:
            w.last_heartbeat = self.clock()

    def check_liveness(self):
        now = self.clock()
        for w in list(self.workers.values()):
            if w.alive and now - w.last_heartbeat > self.cfg.heartbeat_timeout:
                self.on_worker_failed(w.id, reason="heartbeat timeout")

    # -- submission ----------------------------------------------------------

    def submit(self, spec: TaskSpec, deps: Optional[List[ObjectRef]] = None) -> Task:
        bucket = self._rate_limits.get(spec.tenant_id)
        if bucket is not None and not bucket.try_take(self.clock()):
            # surfaced exactly like a quota reject: the submit call raises,
            # nothing is admitted, nothing is left half-registered
            self.stats["rate_limited"] += 1
            raise RateLimitExceeded(
                f"tenant {spec.tenant_id!r} over submit rate "
                f"({bucket.rate_per_s:g}/s, burst {bucket.burst:g})")
        task = Task(spec=spec, deps=list(deps or []))
        task.submitted_clock = self.clock()   # sojourn measured on OUR clock
        self._tenant_state(spec.tenant_id)   # auto-register at weight 1.0
        for d in task.deps:
            self.store.add_ref(d)
            if self.store.locations(d):
                # dep already materialized (e.g. cluster.put artifacts)
                self.graph.mark_available(d.id)
        self.graph.add(task)
        self._note_task_added(task)
        if task.state == TaskState.PENDING:
            # a dep may have been dropped before submission (e.g. its node
            # was retired on the drop path): lineage re-executes producers;
            # deterministic output ids make the reborn object wake this task
            self._reconstruct_missing(task)
        self.schedule()
        return task

    # -- core scheduling pass --------------------------------------------------

    def _locality_score(self, task: Task, worker: WorkerInfo) -> float:
        """Byte-weighted locality: dependency bytes already resident on
        `worker` -- exactly the traffic the data plane does NOT have to
        move if the task lands there. Fat deps dominate the placement the
        way they dominate the fetch, which is the point."""
        score = 0.0
        for d in task.deps:
            if worker.id in self.store.locations(d):
                score += self.store.size_of(d)
        return score * self.cfg.locality_weight

    def _pick_worker(self, task: Task) -> Optional[WorkerInfo]:
        req = task.spec.resources
        if task.spec.placement_group:
            bound = self._placement_bindings.get(task.spec.placement_group, {})
            wid = bound.get(task.spec.bundle_index)
            if wid is not None:
                w = self.workers.get(wid)
                return w if (w and w.alive and w.fits(req)) else None
        if self.cfg.placement_mode == "linear":
            return self._pick_worker_linear(task)
        return self._pick_worker_indexed(task)

    def _pick_worker_linear(self, task: Task) -> Optional[WorkerInfo]:
        """Reference O(n) scan (the seed implementation); kept as the oracle
        for the indexed fast-path and for the benchmark baseline."""
        req = task.spec.resources
        best, best_key = None, None
        for w in self.workers.values():
            if not w.alive or w.draining or not w.fits(req):
                continue
            score = self._locality_score(task, w)
            # the idle-link tiebreak applies only between dep holders
            # (score > 0) -- mirroring the indexed fast-path, which sends
            # zero-locality tasks through the load-ordered heap instead
            key = (score, -self.store.link_load(w.id) if score > 0 else 0.0,
                   -w.load)
            if best_key is None or key > best_key:
                best, best_key = w, key
        return best

    def _pick_worker_indexed(self, task: Task) -> Optional[WorkerInfo]:
        """~O(log n) placement: workers holding the task's deps are scored
        directly (positive locality always beats zero locality), otherwise
        the least-loaded feasible worker comes off the resource-keyed heap."""
        req = task.spec.resources
        if task.deps and self.cfg.locality_weight > 0:
            best, best_key = None, None
            holders = {wid for d in task.deps for wid in self.store.locations(d)}
            for wid in holders:
                w = self.workers.get(wid)
                if w is None or not w.alive or w.draining or not w.fits(req):
                    continue
                score = self._locality_score(task, w)
                if score <= 0:
                    continue
                # equal bytes co-located: prefer the worker whose NIC has
                # carried less data-plane traffic (idle-link tiebreak)
                key = (score, -self.store.link_load(wid), -w.load,
                       -self.index._seq.get(wid, 0))
                if best_key is None or key > best_key:
                    best, best_key = w, key
            if best is not None:
                return best
        return self.index.pick(req)

    def _try_launch(self, task: Task, infeasible: set,
                    sig: Any = _SIG_UNSET) -> bool:
        """Place-and-launch one READY task; shared by the FIFO and fair
        dispatch loops. `infeasible` is the per-pass feasibility memo:
        availability only shrinks within a pass, so a resource signature
        that failed once cannot place later in it (placement-group tasks
        are exempt -- their binding is per-bundle). The sharded scan
        passes the signature it already carries in the queue entry; the
        seed path computes it here."""
        if sig is _SIG_UNSET:
            sig = None
            if not task.spec.placement_group:
                sig = tuple(sorted(task.spec.resources.items()))
        if sig is not None and sig in infeasible:
            return False
        w = self._pick_worker(task)
        if w is None:
            if sig is not None:
                infeasible.add(sig)
            return False
        task.state = TaskState.RUNNING
        task.worker = w.id
        task.started_at = self.clock()
        task.attempts += 1
        w.acquire(task.spec.resources)
        w.running.add(task.id)
        self.index.touch(w)
        ts = self._tenant_state(task.spec.tenant_id)
        ts.launched += 1
        self._usage_add(task.spec.tenant_id, task.spec.resources, +1.0)
        self.stats["launched"] += 1
        self.launch_fn(task, w.id)
        return True

    def _note_task_added(self, task: Task):
        """Record a task's graph-insertion order -- the FIFO tiebreak the
        sharded ready heaps need to reproduce the seed's *stable* sort by
        submitted_at -- and enqueue it if it was born READY."""
        if task.id not in self._task_seq:
            self._task_seq[task.id] = self._next_task_seq
            self._next_task_seq += 1
        self._enqueue_ready(task)

    def _enqueue_ready(self, task: Task):
        """Incremental READY tracking for the sharded dispatch path: push
        a newly-READY task onto its tenant's shard queue. No-op at
        shards=1 (the seed path rescans the whole graph) and for
        non-READY tasks; duplicate pushes are absorbed by the per-shard
        queued set."""
        if self.cfg.shards <= 1 or task.state != TaskState.READY:
            return
        si = shard_key(task.spec.tenant_id, self.cfg.shards)
        if task.id in self._queued[si]:
            return
        self._queued[si].add(task.id)
        seq = self._task_seq.get(task.id, self._next_task_seq)
        sig = None
        if not task.spec.placement_group:
            sig = tuple(sorted(task.spec.resources.items()))
        shard = self._ready_shards[si]
        q = shard.get(task.spec.tenant_id)
        if q is None:
            q = shard[task.spec.tenant_id] = _ReadyQueue()
        q.enqueue((task.submitted_at, seq, task.id, sig))

    def schedule(self):
        if self.cfg.shards > 1:
            self._schedule_sharded()
            return
        ready = self.graph.ready_tasks()
        if not ready:
            return
        infeasible: set = set()
        by_tenant: Dict[str, List[Task]] = {}
        for t in ready:
            by_tenant.setdefault(t.spec.tenant_id, []).append(t)
        if len(by_tenant) <= 1 or self.cfg.dispatch_policy == "fifo":
            # single-tenant (or FIFO baseline): the seed's global
            # arrival-order pass, byte-for-byte the old behavior
            for task in sorted(ready, key=lambda t: t.submitted_at):
                self._try_launch(task, infeasible)
            return
        self._schedule_fair(by_tenant, infeasible)

    def _schedule_sharded(self):
        """Dispatch pass over the per-shard ready queues. Unlike the seed
        path (and an earlier drain-and-reenqueue cut of this one, which
        churned every queued entry per event and gave the asymptotic win
        right back), the queues are *persistent*: entries stay in place
        across passes, launched and stale ones are deleted where they sit,
        and the signature index lets a pass discard a whole blocked
        backlog in O(distinct sigs). Order and launch set are exactly the
        seed's: within a tenant the (submitted_at, insertion-seq) sort is
        the seed's stable sort, and skipping a signature the per-pass memo
        already condemned is precisely what _try_launch would do anyway."""
        infeasible: set = set()
        queues: Dict[str, _ReadyQueue] = {}
        for shard in self._ready_shards:
            for tenant_id in list(shard):
                q = shard[tenant_id]
                if q.entries:
                    queues[tenant_id] = q
                else:
                    del shard[tenant_id]
        if not queues:
            return
        if len(queues) == 1:
            # single-tenant: the seed's global arrival-order pass
            tenant_id, q = next(iter(queues.items()))
            self._scan_queue(tenant_id, q, infeasible)
        elif self.cfg.dispatch_policy == "fifo":
            self._schedule_fifo_merged(queues, infeasible)
        else:
            self._schedule_fair_sharded(queues, infeasible)

    def _scan_queue(self, tenant_id: str, q: _ReadyQueue, infeasible: set,
                    start: int = 0, first_only: bool = False
                    ) -> Tuple[bool, int]:
        """Try one tenant's queued tasks in arrival order from `start`.
        Launched and no-longer-READY entries are deleted in place; entries
        whose signature already failed this pass are stepped over (the
        memo makes retrying them pointless until capacity frees). With
        first_only the scan stops after one placement (the fair picker's
        one-placement-per-turn contract). Returns (placed, resume index)."""
        queued = self._queued[shard_key(tenant_id, self.cfg.shards)]
        entries = q.sorted_entries()
        i = start
        placed = False
        while i < len(entries):
            _, _, task_id, sig = entries[i]
            task = self.graph.tasks.get(task_id)
            if task is None or task.state != TaskState.READY:
                q.remove_at(i)
                queued.discard(task_id)
                continue
            if sig is not None and sig in infeasible:
                i += 1
                continue
            if self._try_launch(task, infeasible, sig=sig):
                q.remove_at(i)
                queued.discard(task_id)
                placed = True
                if first_only:
                    break
            else:
                i += 1
                # a fresh signature just joined the memo: if the queue now
                # holds nothing else, stop instead of stepping the tail
                if q.all_infeasible(infeasible):
                    break
        return placed, i

    def _schedule_fair_sharded(self, queues: Dict[str, _ReadyQueue],
                               infeasible: set):
        """Sharded twin of _schedule_fair: identical DRF arbitration and
        within-tenant ordering, but over the persistent queues -- and a
        tenant whose queue holds only signatures that already failed this
        pass is discarded in O(sigs) without touching its backlog."""
        totals = self._cluster_totals()
        cursor = {tid: 0 for tid in queues}
        active = set(queues)
        while active:
            tid = min(active,
                      key=lambda t: (self._dominant_share(
                          self._tenant_state(t), totals), t))
            q = queues[tid]
            if q.all_infeasible(infeasible):
                active.discard(tid)
                continue
            placed, i = self._scan_queue(tid, q, infeasible,
                                         start=cursor[tid], first_only=True)
            cursor[tid] = i
            if not placed or i >= len(q.entries):
                active.discard(tid)

    def _schedule_fifo_merged(self, queues: Dict[str, _ReadyQueue],
                              infeasible: set):
        """Multi-tenant FIFO baseline (non-default policy): merge every
        queue back to global arrival order and try each task once, exactly
        the seed pass. This path keeps the simple rebuild-after-the-pass
        shape -- it exists for A/B comparison, not for the hot path."""
        merged = []
        for tenant_id, q in queues.items():
            merged.extend((key, tenant_id) for key in q.sorted_entries())
        merged.sort()
        done: set = set()
        for key, tenant_id in merged:
            task_id = key[2]
            task = self.graph.tasks.get(task_id)
            if task is None or task.state != TaskState.READY:
                done.add(task_id)
            elif self._try_launch(task, infeasible):
                done.add(task_id)
        if not done:
            return
        for tenant_id, q in queues.items():
            queued = self._queued[shard_key(tenant_id, self.cfg.shards)]
            kept = [k for k in q.entries if k[2] not in done]
            if len(kept) != len(q.entries):
                queued.difference_update(
                    k[2] for k in q.entries if k[2] in done)
                q.rebuild(kept)

    def _schedule_fair(self, by_tenant: Dict[str, List[Task]],
                       infeasible: set):
        """Weighted fair-share dispatch (DRF-style): repeatedly give the
        next placement to the tenant with the smallest weighted dominant
        share, taking its tasks in arrival order. Within a tenant the
        ordering (and the infeasible-signature memo) matches the FIFO pass,
        so placement-group and drain semantics are unchanged -- only the
        interleave *between* tenants differs."""
        queues = {tid: sorted(tasks, key=lambda t: t.submitted_at)
                  for tid, tasks in by_tenant.items()}
        cursor = {tid: 0 for tid in queues}
        totals = self._cluster_totals()
        active = set(queues)
        while active:
            tid = min(active,
                      key=lambda t: (self._dominant_share(
                          self._tenant_state(t), totals), t))
            q, i = queues[tid], cursor[tid]
            placed = False
            while i < len(q):
                task = q[i]
                i += 1
                if task.state != TaskState.READY:
                    continue
                if self._try_launch(task, infeasible):
                    placed = True
                    break
            cursor[tid] = i
            if not placed or i >= len(q):
                # nothing placeable left for this tenant this pass
                active.discard(tid)
                continue

    # -- completion events -----------------------------------------------------

    def on_task_finished(self, task_id: str, output: ObjectRef,
                         worker_id: Optional[str] = None):
        task = self.graph.tasks.get(task_id)
        if task is None or task.state not in (TaskState.RUNNING,):
            return
        if worker_id is not None and task.worker != worker_id:
            return   # stale report from a preempted/reassigned attempt
        task.state = TaskState.FINISHED
        task.finished_at = self.clock()
        task.output = output
        self._release(task)
        self.stats["finished"] += 1
        self._tenant_state(task.spec.tenant_id).finished += 1
        # submit -> result sojourn, one observation per finish: the
        # conformance checker holds each tenant's histogram count
        # against TenantState.finished, so a dropped observation (or a
        # double-counted one) fails the chaos suite
        if task.submitted_clock is not None:
            self.metrics.histogram(
                "syndeo_task_sojourn_seconds",
                tenant=task.spec.tenant_id).observe(
                    max(0.0, task.finished_at - task.submitted_clock))
        rt = task.runtime
        if rt is not None:
            self._group_runtimes.setdefault(task.spec.group, []).append(rt)
        # cancel the twin (speculation): first finisher wins. The reverse
        # map makes both directions O(1); the seed scanned every task
        # here, which dominated head CPU at high completion rates.
        twins = []
        for tid2 in (task.speculative_of, self._twin_of.get(task.id)):
            t2 = self.graph.tasks.get(tid2) if tid2 else None
            if t2 is not None:
                twins.append(t2)
        for t in twins:
            if t.state == TaskState.RUNNING:
                t.state = TaskState.CANCELLED
                self._release(t)
                self.stats["cancelled"] += 1
                self.cancel_fn(t, t.worker)
        for ready in self.graph.object_available(output):
            self._enqueue_ready(ready)
        self.schedule()

    def on_task_failed(self, task_id: str, error: str,
                       worker_id: Optional[str] = None):
        task = self.graph.tasks.get(task_id)
        if task is None or task.state != TaskState.RUNNING:
            return
        if worker_id is not None and task.worker != worker_id:
            return   # stale report from a preempted/reassigned attempt
        self._release(task)
        self.stats["failed"] += 1
        if task.attempts <= task.spec.max_retries:
            task.state = TaskState.READY if self._deps_live(task) else TaskState.PENDING
            if task.state == TaskState.PENDING:
                self.graph.rewait(task)
            task.error = error
            self._enqueue_ready(task)
            self.stats["retried"] += 1
            self._reconstruct_missing(task)
        else:
            task.state = TaskState.FAILED
            task.error = error
        self.schedule()

    def _release(self, task: Task):
        w = self.workers.get(task.worker or "")
        if w and task.id in w.running:
            w.running.discard(task.id)
            w.release(task.spec.resources)
            self._usage_add(task.spec.tenant_id, task.spec.resources, -1.0)
            self.index.touch(w)

    # -- failure handling --------------------------------------------------------

    def on_worker_failed(self, worker_id: str, reason: str = "failure"):
        w = self.workers.get(worker_id)
        if w is None:
            return
        if w.alive and not w.draining:       # a dying drain was already
            self._totals_add(w, -1.0)        # subtracted at begin_drain
        w.alive = False
        lost_objects = self.store.unregister_node(worker_id)
        for oid in lost_objects:
            self.graph.object_lost(oid)
        # requeue running tasks
        for tid in list(w.running):
            task = self.graph.tasks[tid]
            self._release(task)
            if task.attempts <= task.spec.max_retries:
                task.state = TaskState.READY if self._deps_live(task) else TaskState.PENDING
                if task.state == TaskState.PENDING:
                    self.graph.rewait(task)
                self._enqueue_ready(task)
                self.stats["retried"] += 1
                self._reconstruct_missing(task)
            else:
                task.state = TaskState.FAILED
                task.error = f"worker {worker_id} {reason}"
        self.index.remove(worker_id)
        self._drains.pop(worker_id, None)    # a dying drain is just a failure
        for aid in sorted(w.actors):         # replicas died with the node:
            info = self.actors.pop(aid, None)  # the router re-routes, the
            if info is not None:               # SLO policy respawns
                self._usage_add(info.tenant_id, info.resources, -1.0)
                self.stats["actors_lost"] += 1
        del self.workers[worker_id]
        # the dead node may be the *destination* of other drains' in-flight
        # moves (the store already aborted the matching two-phase records):
        # put those objects back on the planning table immediately instead
        # of waiting out the migration timeout
        for wid2, st in list(self._drains.items()):
            stale = [oid for oid, (dst, _sz) in st.inflight_to.items()
                     if dst == worker_id]
            for oid in stale:
                self.note_migration_failed(wid2, ObjectRef(oid))
            if stale:
                self._dispatch_moves(wid2)
        self.schedule()

    def _deps_live(self, task: Task) -> bool:
        return all(self.store.locations(d) for d in task.deps)

    def _reconstruct_missing(self, task: Task):
        """Lineage reconstruction: re-submit producers of lost deps."""
        for d in task.deps:
            if self.store.locations(d):
                continue
            producer_id = self.store.lineage(d) or d.producer_task
            producer = self.graph.tasks.get(producer_id or "")
            if producer is None:
                continue
            if producer.state in (TaskState.FINISHED, TaskState.FAILED,
                                  TaskState.CANCELLED):
                producer.state = TaskState.READY if self._deps_live(producer) \
                    else TaskState.PENDING
                if producer.state == TaskState.PENDING:
                    self.graph.rewait(producer)
                producer.attempts = 0
                producer.output = None
                self._enqueue_ready(producer)
                self.store.note_reconstruction()
                self.stats["reconstructed"] += 1
                self._reconstruct_missing(producer)  # recursive lineage

    # -- straggler mitigation ------------------------------------------------------

    def check_stragglers(self):
        if not self.cfg.enable_speculation:
            return
        now = self.clock()
        for task in self.graph.running_tasks():
            if task.speculated or task.speculative_of:
                continue
            hist = self._group_runtimes.get(task.spec.group, [])
            if len(hist) < self.cfg.speculation_min_samples:
                continue
            median = sorted(hist)[len(hist) // 2]
            started = task.started_at if task.started_at is not None else now
            if (now - started) > self.cfg.speculation_factor * median:
                twin = Task(spec=task.spec, deps=list(task.deps),
                            speculative_of=task.id)
                task.speculated = True
                self.graph.add(twin)
                self._note_task_added(twin)
                self._twin_of[task.id] = twin.id
                self.stats["speculative"] += 1
        self.schedule()

    # -- placement groups -----------------------------------------------------------

    def create_placement_group(self, name: str,
                               bundles: List[Dict[str, float]],
                               strategy: str = "SPREAD") -> bool:
        """Reserve resources for a gang; returns False if unsatisfiable."""
        binding: Dict[int, str] = {}
        used: Dict[str, Dict[str, float]] = {}
        workers = [w for w in self.workers.values()
                   if w.alive and not w.draining]
        for i, bundle in enumerate(bundles):
            placed = False
            for w in sorted(workers, key=lambda w: len(w.running)):
                if strategy == "STRICT_SPREAD" and w.id in binding.values():
                    continue
                tentative = used.setdefault(w.id, {})
                avail = {k: w.available.get(k, 0.0) - tentative.get(k, 0.0)
                         for k in bundle}
                if all(avail[k] >= v for k, v in bundle.items()):
                    for k, v in bundle.items():
                        tentative[k] = tentative.get(k, 0.0) + v
                    binding[i] = w.id
                    placed = True
                    break
            if not placed:
                return False
        self._placement_bindings[name] = binding
        return True

    def placement_binding(self, name: str) -> Dict[int, str]:
        return dict(self._placement_bindings.get(name, {}))

    def request_placement_group(self, name: str,
                                bundles: List[Dict[str, float]],
                                strategy: str = "SPREAD") -> bool:
        """Like create_placement_group, but an unsatisfiable gang is parked
        as *pending demand* (visible to the autoscaler) and retried whenever
        a worker joins, instead of being dropped on the floor."""
        if self.create_placement_group(name, bundles, strategy):
            self._pending_groups.pop(name, None)
            return True
        self._pending_groups[name] = (list(bundles), strategy)
        return False

    def pending_placement_groups(self) -> Dict[str, Tuple[List[Dict[str, float]], str]]:
        return dict(self._pending_groups)

    def _retry_pending_groups(self):
        for name in list(self._pending_groups):
            bundles, strategy = self._pending_groups[name]
            if self.create_placement_group(name, bundles, strategy):
                del self._pending_groups[name]
