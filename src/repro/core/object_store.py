"""Global Object Store -- the Syndeo/Ray data plane.

Jobs get their data dependencies from the store and push artifacts back to
it (paper Fig. 1). This implementation provides:

  * ref-counted objects with owner tracking (who holds a copy),
  * LRU spill-to-disk when a node store exceeds its capacity,
  * lineage: every object remembers the task that produced it, so the
    scheduler can *reconstruct* objects lost to node failures by
    re-executing the producing task (Ray-style fault tolerance),
  * capability-scoped access (security.py tokens) -- multi-tenant safety.

Payloads are arbitrary picklable python objects / numpy arrays. On a real
TPU cluster large tensors move as sharded checkpoint files instead; the
store then carries references (paths + manifests), which is exactly how the
paper's shared-filesystem rendezvous behaves.

Drain / migration
-----------------

When the scheduler retires a worker gracefully (DRAINING lifecycle state,
`scheduler.begin_drain`), objects whose *only* copy lives on the retiring
node are **migrated** to a survivor instead of being dropped and later
rebuilt by lineage re-execution:

  * `objects_on(node)` enumerates directory entries held on a node and
    whether the node is the sole holder -- the scheduler's migration
    planner reads this to decide what must move,
  * `migrate(ref, src, dst)` copies the raw blob between node stores
    without a pickle round-trip, records the new location, drops the old
    one, and **hands off ownership** if the source owned the object; the
    move is capability-checked when the cluster installs a migration
    capability (`set_migration_guard`), so a tenant cannot exfiltrate
    another tenant's objects by draining a shared node,
  * after migration `unregister_node(src)` loses nothing: every hot
    object is served from a survivor, so no lineage reconstruction fires
    (the drain-vs-drop benchmark and the fault-tolerance property tests
    assert exactly this).

Cold objects (zero refcount, not depended on) are simply dropped -- the
drain is then provably no worse than recompute: it never re-executes a
producer for a hot object, and never copies garbage.
"""
from __future__ import annotations

import os
import pickle
import threading
import time
import uuid
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Set


@dataclass(frozen=True)
class ObjectRef:
    id: str
    size: int = 0
    producer_task: Optional[str] = None

    @staticmethod
    def fresh(producer_task: Optional[str] = None, size: int = 0) -> "ObjectRef":
        return ObjectRef(id=uuid.uuid4().hex, size=size,
                         producer_task=producer_task)


class NodeStore:
    """Per-node object store with LRU spill to a scratch directory."""

    def __init__(self, node_id: str, capacity_bytes: int = 1 << 30,
                 spill_dir: Optional[str] = None):
        self.node_id = node_id
        self.capacity = capacity_bytes
        self.spill_dir = spill_dir
        self._mem: "OrderedDict[str, bytes]" = OrderedDict()
        self._spilled: Dict[str, str] = {}
        self._used = 0
        self._lock = threading.Lock()
        self.stats = {"puts": 0, "gets": 0, "spills": 0, "restores": 0}

    def put(self, ref: ObjectRef, value: Any) -> int:
        blob = pickle.dumps(value, protocol=pickle.HIGHEST_PROTOCOL)
        with self._lock:
            old = self._mem.pop(ref.id, None)
            if old is not None:            # re-put (e.g. reconstruction)
                self._used -= len(old)
            self._mem[ref.id] = blob
            self._mem.move_to_end(ref.id)
            self._used += len(blob)
            self.stats["puts"] += 1
            self._maybe_spill()
        return len(blob)

    def get(self, ref: ObjectRef) -> Any:
        with self._lock:
            self.stats["gets"] += 1
            if ref.id in self._mem:
                self._mem.move_to_end(ref.id)
                return pickle.loads(self._mem[ref.id])
            if ref.id in self._spilled:
                path = self._spilled[ref.id]
                with open(path, "rb") as f:
                    blob = f.read()
                self.stats["restores"] += 1
                self._mem[ref.id] = blob
                self._used += len(blob)
                self._maybe_spill()
                return pickle.loads(blob)
        raise KeyError(f"object {ref.id} not on node {self.node_id}")

    def has(self, ref: ObjectRef) -> bool:
        with self._lock:
            return ref.id in self._mem or ref.id in self._spilled

    def delete(self, ref: ObjectRef):
        with self._lock:
            blob = self._mem.pop(ref.id, None)
            if blob is not None:
                self._used -= len(blob)
            path = self._spilled.pop(ref.id, None)
            if path and os.path.exists(path):
                os.unlink(path)

    def export_blob(self, ref: ObjectRef) -> bytes:
        """Raw serialized bytes for migration (no pickle round-trip)."""
        with self._lock:
            if ref.id in self._mem:
                return self._mem[ref.id]
            if ref.id in self._spilled:
                with open(self._spilled[ref.id], "rb") as f:
                    return f.read()
        raise KeyError(f"object {ref.id} not on node {self.node_id}")

    def import_blob(self, ref: ObjectRef, blob: bytes):
        """Accept migrated bytes verbatim (counterpart of export_blob)."""
        with self._lock:
            if ref.id in self._mem or ref.id in self._spilled:
                return
            self._mem[ref.id] = blob
            self._used += len(blob)
            self.stats["puts"] += 1
            self._maybe_spill()

    def _maybe_spill(self):
        """LRU spill until under capacity (lock held)."""
        if self.spill_dir is None:
            return
        while self._used > self.capacity and self._mem:
            oid, blob = self._mem.popitem(last=False)
            self._used -= len(blob)
            os.makedirs(self.spill_dir, exist_ok=True)
            path = os.path.join(self.spill_dir, f"{self.node_id}_{oid}.obj")
            with open(path, "wb") as f:
                f.write(blob)
            self._spilled[oid] = path
            self.stats["spills"] += 1


@dataclass
class _Directory:
    locations: Set[str] = field(default_factory=set)
    refcount: int = 1
    producer_task: Optional[str] = None
    size: int = 0
    created: float = field(default_factory=time.monotonic)
    owner: Optional[str] = None       # node accountable for the primary copy


class GlobalObjectStore:
    """Head-side directory over the per-node stores.

    Tracks locations, refcounts and lineage; transfers objects between node
    stores on demand (locality misses are recorded -- the benchmark's
    communication-cost model reads these counters).
    """

    def __init__(self):
        self._dir: Dict[str, _Directory] = {}
        self._nodes: Dict[str, NodeStore] = {}
        self._lock = threading.Lock()
        self._migration_guard = None   # optional (capability, token) pair
        self.stats = {"transfers": 0, "transfer_bytes": 0,
                      "reconstructions": 0,
                      "migrations": 0, "migrated_bytes": 0}

    def register_node(self, store: NodeStore):
        with self._lock:
            self._nodes[store.node_id] = store

    def unregister_node(self, node_id: str) -> Set[str]:
        """Remove a (failed) node; returns ids of objects that lost their
        last copy (candidates for lineage reconstruction)."""
        lost = set()
        with self._lock:
            self._nodes.pop(node_id, None)
            for oid, entry in self._dir.items():
                entry.locations.discard(node_id)
                if entry.owner == node_id:
                    # owner handoff to any surviving holder
                    entry.owner = next(iter(entry.locations), None)
                if not entry.locations:
                    lost.add(oid)
        return lost

    def has_node(self, node_id: str) -> bool:
        with self._lock:
            return node_id in self._nodes

    def put(self, node_id: str, value: Any,
            producer_task: Optional[str] = None,
            ref_id: Optional[str] = None) -> ObjectRef:
        """Store a new object. `ref_id` pins a deterministic object id
        (Ray-style): a reconstructed producer re-puts under the *same* id,
        so tasks waiting on the original ref wake up when it reappears."""
        ref = (ObjectRef(ref_id, 0, producer_task) if ref_id
               else ObjectRef.fresh(producer_task))
        size = self._nodes[node_id].put(ref, value)
        with self._lock:
            e = self._dir.get(ref.id)
            if e is not None:              # reconstruction: revive the entry
                e.locations.add(node_id)
                e.size = size
                e.producer_task = producer_task or e.producer_task
                if e.owner is None:
                    e.owner = node_id
            else:
                self._dir[ref.id] = _Directory(locations={node_id},
                                               producer_task=producer_task,
                                               size=size, owner=node_id)
        return ObjectRef(ref.id, size, producer_task)

    def get(self, node_id: str, ref: ObjectRef) -> Any:
        """Fetch on `node_id`, transferring from a remote copy if needed."""
        with self._lock:
            entry = self._dir.get(ref.id)
            local = node_id in (entry.locations if entry else ())
            src = next(iter(entry.locations)) if entry and entry.locations else None
        if local or (entry is None):
            return self._nodes[node_id].get(ref)
        if src is None:
            raise KeyError(f"object {ref.id} has no live copies")
        value = self._nodes[src].get(ref)
        self._nodes[node_id].put(ref, value)
        with self._lock:
            self._dir[ref.id].locations.add(node_id)
            self.stats["transfers"] += 1
            self.stats["transfer_bytes"] += self._dir[ref.id].size
        return value

    def locations(self, ref: ObjectRef) -> Set[str]:
        with self._lock:
            e = self._dir.get(ref.id)
            return set(e.locations) if e else set()

    def size_of(self, ref: ObjectRef) -> int:
        with self._lock:
            e = self._dir.get(ref.id)
            return e.size if e else ref.size

    def lineage(self, ref: ObjectRef) -> Optional[str]:
        with self._lock:
            e = self._dir.get(ref.id)
            return e.producer_task if e else ref.producer_task

    def add_ref(self, ref: ObjectRef, n: int = 1):
        with self._lock:
            if ref.id in self._dir:
                self._dir[ref.id].refcount += n

    def release(self, ref: ObjectRef):
        """Decrement refcount; free all copies at zero."""
        with self._lock:
            e = self._dir.get(ref.id)
            if e is None:
                return
            e.refcount -= 1
            if e.refcount > 0:
                return
            locs = set(e.locations)
            del self._dir[ref.id]
        for node_id in locs:
            store = self._nodes.get(node_id)
            if store is not None:
                store.delete(ref)

    def note_reconstruction(self):
        with self._lock:
            self.stats["reconstructions"] += 1

    # -- drain / migration (see module docstring) -----------------------------

    def set_migration_guard(self, capability, token: str):
        """Require `capability` (right "migrate") for every migrate() call.
        Installed by the cluster head with a capability minted under the
        cluster token -- a tenant without it cannot move objects around."""
        self._migration_guard = (capability, token)

    def owner_of(self, ref: ObjectRef) -> Optional[str]:
        with self._lock:
            e = self._dir.get(ref.id)
            return e.owner if e else None

    def refcount(self, ref_or_id) -> int:
        oid = ref_or_id.id if isinstance(ref_or_id, ObjectRef) else ref_or_id
        with self._lock:
            e = self._dir.get(oid)
            return e.refcount if e else 0

    def objects_on(self, node_id: str) -> Dict[str, "ObjectRef"]:
        """Directory entries with a copy on `node_id`, keyed by object id.
        The migration planner filters these for sole-holder hot objects."""
        out: Dict[str, ObjectRef] = {}
        with self._lock:
            for oid, e in self._dir.items():
                if node_id in e.locations:
                    out[oid] = ObjectRef(oid, e.size, e.producer_task)
        return out

    def sole_holder(self, ref: ObjectRef, node_id: str) -> bool:
        with self._lock:
            e = self._dir.get(ref.id)
            return bool(e) and e.locations == {node_id}

    def migrate(self, ref: ObjectRef, src: str, dst: str) -> bool:
        """Move one object's copy src -> dst (raw blob, no pickle round-trip),
        updating the directory and handing off ownership if src owned it.
        Returns False when the move is moot (object gone, src copy gone, or
        dst unregistered) -- drains treat that as already-done."""
        if self._migration_guard is not None:
            cap, token = self._migration_guard
            cap.check(token, "objects", "migrate")
        with self._lock:
            e = self._dir.get(ref.id)
            src_store = self._nodes.get(src)
            dst_store = self._nodes.get(dst)
            if e is None or src not in e.locations or dst_store is None:
                return False
            already_there = dst in e.locations
            if already_there:                # already replicated there
                e.locations.discard(src)
                if e.owner == src:
                    e.owner = dst
        if already_there:
            if src_store is not None:        # drop the now-unreachable blob
                src_store.delete(ref)
            return True
        if src_store is None:
            return False
        blob = src_store.export_blob(ref)
        dst_store.import_blob(ref, blob)
        with self._lock:
            e = self._dir.get(ref.id)
            if e is None:                    # released mid-copy
                dst_store.delete(ref)
                return False
            e.locations.add(dst)
            e.locations.discard(src)
            if e.owner == src:
                e.owner = dst                # owner handoff
            self.stats["migrations"] += 1
            self.stats["migrated_bytes"] += len(blob)
        src_store.delete(ref)
        return True
