"""known-bad: A->B and B->A lock acquisition order (SYN-L002)."""
import threading


class Ledger:
    def __init__(self, peer: "Mirror"):
        self._lock = threading.Lock()
        self.peer = peer
        self.rows = {}

    def post(self, key, value):
        with self._lock:
            with self.peer._lock:             # Ledger -> Mirror
                self.peer.rows[key] = value


class Mirror:
    def __init__(self, peer: "Ledger"):
        self._lock = threading.Lock()
        self.peer = peer
        self.rows = {}

    def sync(self, key):
        with self._lock:
            with self.peer._lock:             # Mirror -> Ledger: cycle
                return self.peer.rows.get(key)
