"""Multi-tenant control-plane tests.

Covers: tenant key derivation + tenant-scoped capabilities, envelope
replay hardening (authenticated nonce + bounded seen-set), cross-tenant
isolation on the object store (get/put/migrate, including drain
migration), per-tenant byte/ref quotas (reject and spill policies),
weighted fair-share (DRF) dispatch vs the FIFO baseline, per-tenant
autoscaler floors, and the end-to-end threaded cluster path."""
import time

import pytest

from repro.core import (Autoscaler, AutoscalerConfig, Capability, NonceCache,
                        QuotaExceededError, Scheduler, SchedulerConfig,
                        SecurityError, SimCluster, SimCostModel,
                        SyndeoCluster, TaskSpec, TaskState, Tenant,
                        TenantQuota, WorkerInfo)
from repro.core.object_store import GlobalObjectStore, NodeStore
from repro.core.security import (ADMIN_TENANT, mint_cluster_token,
                                 open_sealed, seal, tenant_key)


# -------------------------------------------------------- tenant capabilities

def test_tenant_key_is_derived_and_stable():
    tok = mint_cluster_token()
    assert tenant_key(tok, "alice") == tenant_key(tok, "alice")
    assert tenant_key(tok, "alice") != tenant_key(tok, "bob")
    assert tenant_key(tok, "alice") != tok
    with pytest.raises(SecurityError):
        tenant_key(tok, ADMIN_TENANT)   # the admin scope is not derivable


def test_tenant_capability_verifies_only_its_own_tenant():
    tok = mint_cluster_token()
    cap = Capability.grant_for_tenant(tok, "alice", "obj1", "get")
    cap.verify(tok, "obj1", "get", object_tenant="alice")
    with pytest.raises(SecurityError, match="cross-tenant"):
        cap.verify(tok, "obj1", "get", object_tenant="bob")
    with pytest.raises(SecurityError):
        cap.verify(tok, "obj2", "get", object_tenant="alice")  # wrong object
    with pytest.raises(SecurityError):
        cap.verify(tok, "obj1", "put", object_tenant="alice")  # wrong right


def test_tenant_capability_cannot_be_relabeled():
    """Changing the tenant id on a minted capability breaks the MAC: the
    tenant id is inside the signed bytes, under a *different* derived key."""
    tok = mint_cluster_token()
    cap = Capability.grant_for_tenant(tok, "alice", "obj1", "get")
    forged = Capability(cap.object_id, cap.right, cap.mac, tenant_id="bob")
    with pytest.raises(SecurityError):
        forged.verify(tok, "obj1", "get", object_tenant="bob")


def test_admin_capability_covers_every_tenant():
    tok = mint_cluster_token()
    cap = Capability.grant(tok, "objects", "migrate")
    assert cap.tenant_id == ADMIN_TENANT
    cap.verify(tok, "objects", "migrate", object_tenant="alice")
    cap.verify(tok, "objects", "migrate", object_tenant="bob")


def test_tenant_principal_mints_equivalent_grants():
    """A Tenant holding only its derived key mints capabilities identical
    to head-side grant_for_tenant -- it never needs the cluster token."""
    tok = mint_cluster_token()
    alice = Tenant.derive(tok, "alice", weight=2.0)
    assert alice.key != tok
    cap = alice.grant("obj1", "get")
    assert cap == Capability.grant_for_tenant(tok, "alice", "obj1", "get")


# ------------------------------------------------------------ replay hardening

def test_sealed_envelope_replay_is_rejected():
    tok = mint_cluster_token()
    cache = NonceCache()
    env = seal(tok, {"op": "poll", "worker": "w0"})
    assert open_sealed(tok, env, nonce_cache=cache)["op"] == "poll"
    with pytest.raises(SecurityError, match="replay"):
        open_sealed(tok, env, nonce_cache=cache)
    # a fresh seal of the same body is a new message, not a replay
    open_sealed(tok, seal(tok, {"op": "poll", "worker": "w0"}),
                nonce_cache=cache)


def test_envelope_timestamp_and_nonce_are_authenticated():
    tok = mint_cluster_token()
    env = seal(tok, {"op": "join"})
    stale = dict(env, ts=env["ts"] - 7200.0)       # re-stamp: breaks the MAC
    with pytest.raises(SecurityError, match="HMAC"):
        open_sealed(tok, stale)
    renonced = dict(env, nonce="00" * 16)          # re-nonce: breaks the MAC
    with pytest.raises(SecurityError, match="HMAC"):
        open_sealed(tok, renonced, nonce_cache=NonceCache())


def test_nonce_cache_is_bounded():
    cache = NonceCache(max_entries=4)
    for i in range(10):
        cache.check_and_add(f"nonce-{i}")
    assert len(cache) == 4
    with pytest.raises(SecurityError):             # still present -> replay
        cache.check_and_add("nonce-9")
    cache.check_and_add("nonce-0")                 # evicted long ago: aged out


# ------------------------------------------------- store: cross-tenant access

def _store_with_two_tenants():
    tok = mint_cluster_token()
    g = GlobalObjectStore()
    g.set_access_guard(tok)
    g.register_node(NodeStore("n0"))
    g.register_node(NodeStore("n1"))
    ref_a = g.put("n0", {"who": "alice"}, tenant="alice")
    ref_b = g.put("n0", {"who": "bob"}, tenant="bob")
    return tok, g, ref_a, ref_b


def test_cross_tenant_get_denied():
    tok, g, ref_a, ref_b = _store_with_two_tenants()
    cap_a = Capability.grant_for_tenant(tok, "alice", ref_b.id, "get")
    with pytest.raises(SecurityError, match="cross-tenant"):
        g.get("n1", ref_b, capability=cap_a)
    # the right capability works, and alice still reads her own data
    cap_b = Capability.grant_for_tenant(tok, "bob", ref_b.id, "get")
    assert g.get("n1", ref_b, capability=cap_b)["who"] == "bob"
    own = Capability.grant_for_tenant(tok, "alice", ref_a.id, "get")
    assert g.get("n1", ref_a, capability=own)["who"] == "alice"


def test_cross_tenant_put_denied():
    tok, g, ref_a, _ = _store_with_two_tenants()
    # bob cannot overwrite alice's object id, with or without a capability
    with pytest.raises(SecurityError, match="cross-tenant"):
        g.put("n0", {"evil": True}, ref_id=ref_a.id, tenant="bob")
    cap = Capability.grant_for_tenant(tok, "bob", "newobj", "put")
    with pytest.raises(SecurityError):
        g.put("n0", {"x": 1}, ref_id="newobj", tenant="alice",
              capability=cap)   # capability tenant != claimed tenant


def test_cross_tenant_migrate_denied():
    tok, g, ref_a, ref_b = _store_with_two_tenants()
    cap_a = Capability.grant_for_tenant(tok, "alice", "objects", "migrate")
    with pytest.raises(SecurityError, match="cross-tenant"):
        g.migrate(ref_b, "n0", "n1", capability=cap_a)
    assert g.locations(ref_b) == {"n0"}            # nothing moved
    # the admin guard (what the head installs) moves anything
    admin = Capability.grant(tok, "objects", "migrate")
    assert g.migrate(ref_b, "n0", "n1", capability=admin)
    assert g.locations(ref_b) == {"n1"}


def test_drain_migration_respects_tenant_guard():
    """A drain running under a *tenant-scoped* migration guard cannot
    exfiltrate another tenant's objects: the denied move degrades to the
    drop path (lineage) instead of crossing the tenant boundary."""
    sim = SimCluster(SimCostModel(task_time_s=lambda s: 0.05,
                                  result_bytes=lambda s: 1024.0, jitter=0.0,
                                  result_location="worker"),
                     SchedulerConfig(enable_speculation=False,
                                     heartbeat_timeout=1e9), seed=7)
    tok = mint_cluster_token()
    sim.store.set_access_guard(tok)
    sim.add_workers(3)
    # bob's object lands on some worker
    sim.run_wave([TaskSpec(fn=None, group="produce", tenant_id="bob")])
    ref = next(t.output for t in sim.scheduler.graph.tasks.values()
               if t.output is not None)
    assert sim.store.tenant_of(ref) == "bob"
    victim = next(iter(sim.store.locations(ref)))
    # the drain plane holds only alice's migration capability
    sim.store.set_migration_guard(
        Capability.grant_for_tenant(tok, "alice", "objects", "migrate"), tok)
    sim.drain_worker_at(victim, sim.now)
    sim.run()
    assert sim.scheduler.stats["migration_denied"] >= 1
    assert sim.scheduler.stats["migrated_objects"] == 0
    assert victim not in sim.scheduler.workers     # drain still completed
    # under the admin guard (the head's own), the same drain migrates
    sim2 = SimCluster(SimCostModel(task_time_s=lambda s: 0.05,
                                   result_bytes=lambda s: 1024.0, jitter=0.0,
                                   result_location="worker"),
                      SchedulerConfig(enable_speculation=False,
                                      heartbeat_timeout=1e9), seed=7)
    tok2 = mint_cluster_token()
    sim2.store.set_access_guard(tok2)
    sim2.store.set_migration_guard(
        Capability.grant(tok2, "objects", "migrate"), tok2)
    sim2.add_workers(3)
    sim2.run_wave([TaskSpec(fn=None, group="produce", tenant_id="bob")])
    ref2 = next(t.output for t in sim2.scheduler.graph.tasks.values()
                if t.output is not None)
    victim2 = next(iter(sim2.store.locations(ref2)))
    sim2.drain_worker_at(victim2, sim2.now)
    sim2.run()
    assert sim2.scheduler.stats["migration_denied"] == 0
    assert sim2.scheduler.stats["migrated_objects"] >= 1
    assert sim2.store.locations(ref2)              # bob's object survived


# ------------------------------------------------------------------- quotas

def test_byte_quota_rejects_and_rolls_back():
    g = GlobalObjectStore()
    node = NodeStore("n0")
    g.register_node(node)
    g.set_quota("alice", TenantQuota(max_bytes=4096))
    g.put("n0", b"x" * 1024, tenant="alice")
    with pytest.raises(QuotaExceededError):
        g.put("n0", b"y" * 8192, tenant="alice")
    usage = g.tenant_usage("alice")
    assert usage["refs"] == 1 and usage["bytes"] < 4096
    assert g.stats["quota_rejects"] == 1
    # the rejected blob is not left behind on the node store
    assert node._used == usage["bytes"]
    # other tenants are unaffected
    g.put("n0", b"z" * 8192, tenant="bob")


def test_ref_quota_rejects():
    g = GlobalObjectStore()
    g.register_node(NodeStore("n0"))
    g.set_quota("alice", TenantQuota(max_refs=2))
    g.put("n0", 1, tenant="alice")
    g.put("n0", 2, tenant="alice")
    with pytest.raises(QuotaExceededError, match="ref quota"):
        g.put("n0", 3, tenant="alice")
    assert g.tenant_usage("alice")["refs"] == 2


def test_byte_quota_spill_policy(tmp_path):
    """on_exceed="spill": over-quota puts land on disk instead of memory,
    so a greedy tenant keeps working without squeezing others out."""
    g = GlobalObjectStore()
    node = NodeStore("n0", capacity_bytes=1 << 30, spill_dir=str(tmp_path))
    g.register_node(node)
    g.set_quota("alice", TenantQuota(max_bytes=2048, on_exceed="spill"))
    r1 = g.put("n0", b"a" * 1024, tenant="alice")
    spills_before = node.stats["spills"]
    r2 = g.put("n0", b"b" * 4096, tenant="alice")   # over quota -> disk
    assert node.stats["spills"] == spills_before + 1
    assert g.stats["quota_spills"] == 1
    # both objects stay readable
    assert g.get("n0", r1) == b"a" * 1024
    assert g.get("n0", r2) == b"b" * 4096


def test_byte_quota_spill_without_spill_dir_degrades_to_reject():
    """on_exceed="spill" on a node without a spill dir must reject, not
    silently keep the over-quota blob in memory."""
    g = GlobalObjectStore()
    node = NodeStore("n0")                         # no spill_dir
    g.register_node(node)
    g.set_quota("alice", TenantQuota(max_bytes=512, on_exceed="spill"))
    with pytest.raises(QuotaExceededError, match="no spill dir"):
        g.put("n0", b"x" * 4096, tenant="alice")
    assert g.tenant_usage("alice") == {"bytes": 0, "refs": 0}
    assert node._used == 0                         # fully rolled back
    assert g.stats["quota_spills"] == 0


def test_release_frees_quota():
    g = GlobalObjectStore()
    g.register_node(NodeStore("n0"))
    g.set_quota("alice", TenantQuota(max_refs=1))
    ref = g.put("n0", 1, tenant="alice")
    with pytest.raises(QuotaExceededError):
        g.put("n0", 2, tenant="alice")
    g.release(ref)
    assert g.tenant_usage("alice") == {"bytes": 0, "refs": 0}
    g.put("n0", 2, tenant="alice")                 # admitted again


# ------------------------------------------------------- fair-share dispatch

def _sched_with_workers(n, policy="fair"):
    store = GlobalObjectStore()
    launched = []
    sched = Scheduler(store, lambda t, w: launched.append(t),
                      config=SchedulerConfig(enable_speculation=False,
                                             dispatch_policy=policy))
    for i in range(n):
        sched.add_worker(WorkerInfo(f"w{i}", {"cpu": 1.0}))
    return sched, launched


def _queue_ready(sched, n, tenant):
    """Stage READY tasks without triggering a scheduling pass (the
    contended-queue shape fair-share exists for)."""
    from repro.core.task_graph import Task
    for _ in range(n):
        sched._tenant_state(tenant)
        sched.graph.add(Task(spec=TaskSpec(fn=None, tenant_id=tenant)))


def test_fair_share_interleaves_equal_weights():
    """4 slots, 8 alice tasks queued ahead of 8 bob tasks: FIFO gives all
    4 slots to alice; fair-share splits them 2/2."""
    for policy, expect_alice in (("fair", 2), ("fifo", 4)):
        sched, launched = _sched_with_workers(4, policy)
        _queue_ready(sched, 8, "alice")
        _queue_ready(sched, 8, "bob")
        sched.schedule()
        by = {}
        for t in launched:
            by[t.spec.tenant_id] = by.get(t.spec.tenant_id, 0) + 1
        assert by.get("alice", 0) == expect_alice, (policy, by)
        assert sum(by.values()) == 4


def test_fair_share_honors_weights():
    """Weight 3 vs weight 1 on 4 slots -> 3/1 split of placements."""
    sched, launched = _sched_with_workers(4)
    sched.register_tenant("heavy", weight=3.0)
    sched.register_tenant("light", weight=1.0)
    _queue_ready(sched, 8, "light")
    _queue_ready(sched, 8, "heavy")
    sched.schedule()
    by = {}
    for t in launched:
        by[t.spec.tenant_id] = by.get(t.spec.tenant_id, 0) + 1
    assert by == {"heavy": 3, "light": 1}


def test_single_tenant_fair_matches_fifo_order():
    """With one tenant the fair path must reproduce the seed's arrival
    order exactly (the zero-cost default)."""
    runs = {}
    for policy in ("fair", "fifo"):
        sched, launched = _sched_with_workers(3, policy)
        for i in range(9):
            sched.submit(TaskSpec(fn=None, name=f"t{i}"))
        runs[policy] = [t.spec.name for t in launched]
    assert runs["fair"] == runs["fifo"]


def test_fair_share_tracks_usage_release():
    """Dominant shares decay as tasks finish: usage accounting must be
    symmetric across launch/finish/fail/preempt paths."""
    sched, launched = _sched_with_workers(2)
    t1 = sched.submit(TaskSpec(fn=None, tenant_id="alice"))
    t2 = sched.submit(TaskSpec(fn=None, tenant_id="bob"))
    shares = sched.tenant_shares()
    assert shares["alice"] > 0 and shares["bob"] > 0
    from repro.core.object_store import ObjectRef
    sched.on_task_finished(t1.id, ObjectRef("o1"))
    sched.on_task_failed(t2.id, "boom")
    shares = sched.tenant_shares()
    assert shares["alice"] == 0.0
    # bob's retry relaunched immediately on the freed worker
    assert sched.graph.tasks[t2.id].state == TaskState.RUNNING


def test_fair_share_preserves_placement_groups():
    """Placement-group tasks keep their bundle binding under fair-share."""
    sched, launched = _sched_with_workers(3)
    assert sched.create_placement_group(
        "gang", [{"cpu": 1.0}, {"cpu": 1.0}], strategy="STRICT_SPREAD")
    binding = sched.placement_binding("gang")
    sched.submit(TaskSpec(fn=None, tenant_id="alice",
                          placement_group="gang", bundle_index=0))
    sched.submit(TaskSpec(fn=None, tenant_id="bob",
                          placement_group="gang", bundle_index=1))
    placed = {t.spec.bundle_index: t.worker for t in launched}
    assert placed[0] == binding[0] and placed[1] == binding[1]


# ------------------------------------------------- autoscaler tenant floors

def test_scale_down_respects_tenant_minimums():
    store = GlobalObjectStore()
    sched = Scheduler(store, lambda t, w: None,
                      config=SchedulerConfig(enable_speculation=False))
    now = [100.0]
    sched.clock = lambda: now[0]
    for i in range(6):
        sched.add_worker(WorkerInfo(f"w{i}", {"cpu": 1.0}))
    sched.register_tenant("steady")
    sched.register_tenant("bursty")
    released = []
    auto = Autoscaler(sched, lambda n, r: n, released.extend,
                      AutoscalerConfig(min_workers=1,
                                       tenant_min_workers={"steady": 3,
                                                           "bursty": 1},
                                       idle_timeout_s=0.0,
                                       scale_down_cooldown_s=0.0,
                                       max_scale_down_step=8),
                      clock=lambda: now[0])
    assert auto.effective_min_workers() == 4       # 3 + 1 admitted floors
    for _ in range(4):
        now[0] += 10.0
        auto.tick()
    assert len(sched.workers) == 4                 # not the global min of 1
    # an unadmitted tenant's floor does not count
    auto.cfg.tenant_min_workers["ghost"] = 10
    assert auto.effective_min_workers() == 4


def test_scale_up_reason_attributes_tenants():
    store = GlobalObjectStore()
    sched = Scheduler(store, lambda t, w: None,
                      config=SchedulerConfig(enable_speculation=False))
    sched.add_worker(WorkerInfo("w0", {"cpu": 1.0}))
    auto = Autoscaler(sched, lambda n, r: n, lambda w: None,
                      AutoscalerConfig(queue_depth_per_worker=1.0,
                                       scale_up_cooldown_s=0.0))
    for i in range(4):
        sched.submit(TaskSpec(fn=None, tenant_id="alice"))
    for i in range(2):
        sched.submit(TaskSpec(fn=None, tenant_id="bob"))
    ev = auto.tick()
    assert ev is not None and ev.action == "scale_up"
    assert "alice:" in ev.reason and "bob:" in ev.reason


# ------------------------------------------------- threaded cluster end-to-end

def test_cluster_tenants_end_to_end():
    with SyndeoCluster() as cluster:
        alice = cluster.register_tenant("alice", weight=2.0,
                                        quota_bytes=1 << 20)
        cluster.register_tenant("bob")
        for _ in range(2):
            cluster.add_worker(resources={"cpu": 1.0})
        ta = cluster.submit(lambda: "from-alice", tenant_id="alice")
        tb = cluster.submit(lambda: "from-bob", tenant_id="bob")
        assert cluster.get(ta, timeout=10.0) == "from-alice"
        assert cluster.get(tb, timeout=10.0) == "from-bob"
        # outputs are owned by the right tenants
        assert cluster.store.tenant_of(f"obj-{ta.id}") == "alice"
        assert cluster.store.tenant_of(f"obj-{tb.id}") == "bob"
        assert alice.weight == 2.0
        assert cluster.scheduler.tenants["alice"].finished == 1


def test_cluster_cross_tenant_dep_fails_task():
    """A bob task depending on alice's object fails with a SecurityError:
    the worker fetches deps under the task's tenant capability."""
    with SyndeoCluster() as cluster:
        cluster.register_tenant("alice")
        cluster.register_tenant("bob")
        cluster.add_worker(resources={"cpu": 1.0})
        secret = cluster.put({"alice": "secret"}, tenant_id="alice")
        task = cluster.submit(lambda x: x, deps=[secret], tenant_id="bob",
                              max_retries=0)
        with pytest.raises(RuntimeError, match="cross-tenant"):
            cluster.get(task, timeout=10.0)


def test_cluster_quota_rejects_put():
    with SyndeoCluster() as cluster:
        cluster.register_tenant("alice", quota_bytes=1024)
        with pytest.raises(QuotaExceededError):
            cluster.put(b"x" * 4096, tenant_id="alice")


def test_tcp_poll_cross_tenant_dep_fails_task_not_strands_it():
    """A TCP worker polling a task whose deps are another tenant's objects
    gets no payload, and the task *fails visibly* (retry/FAILED path)
    instead of sitting RUNNING forever."""
    from repro.core.worker import HeadServer

    cluster = SyndeoCluster()
    server = HeadServer(cluster)
    server.attach()
    try:
        joined = server.dispatch({"op": "join", "worker": "tcp-x",
                                  "resources": {"cpu": 1.0}})
        assert joined["ok"]
        secret = cluster.put({"s": 1}, tenant_id="alice")
        task = cluster.submit(lambda x: x, deps=[secret], tenant_id="bob",
                              max_retries=0)
        got = server.dispatch({"op": "poll", "worker": "tcp-x"})
        assert got["ok"] and got["task"] is None
        cur = cluster.scheduler.graph.tasks[task.id]
        assert cur.state == TaskState.FAILED
        assert "cross-tenant" in (cur.error or "")
    finally:
        server.shutdown()
        cluster.shutdown()


def test_tcp_result_over_quota_fails_task_not_strands_it():
    """A TCP worker's result put that trips the tenant's quota must fail
    the task (visible error), not leave it RUNNING with no owner."""
    from repro.core.worker import HeadServer, _enc

    cluster = SyndeoCluster()
    cluster.register_tenant("alice", quota_bytes=64)
    server = HeadServer(cluster)
    server.attach()
    try:
        server.dispatch({"op": "join", "worker": "tcp-y",
                         "resources": {"cpu": 1.0}})
        task = cluster.submit(pow, 2, 10, tenant_id="alice", max_retries=0)
        got = server.dispatch({"op": "poll", "worker": "tcp-y"})
        assert got["task"] == task.id
        reply = server.dispatch({"op": "result", "task": task.id,
                                 "worker": "tcp-y",
                                 "payload": _enc(b"x" * 4096)})
        assert reply["ok"] and reply.get("stored") is False
        cur = cluster.scheduler.graph.tasks[task.id]
        assert cur.state == TaskState.FAILED
        assert "QuotaExceededError" in (cur.error or "")
    finally:
        server.shutdown()
        cluster.shutdown()


# ----------------------------------------- metrics adapter (K8s HPA bridge)

def test_metrics_adapter_serves_scheduler_signals(tmp_path):
    """The custom-metrics adapter polls the head's sealed `metrics` op and
    serves the HPA's two signals over HTTP (the declarative replacement
    for the imperative kubectl-scale script)."""
    import json
    import threading
    import urllib.request

    from repro.core.metrics_adapter import MetricsPoller, make_server
    from repro.core.rendezvous import FileRendezvous
    from repro.core.worker import HeadServer

    cluster = SyndeoCluster(rendezvous=FileRendezvous(str(tmp_path)))
    server = HeadServer(cluster)
    try:
        # 6 tasks, no workers: backlog 6, busy fraction 0
        for _ in range(6):
            cluster.submit(lambda: None, tenant_id="alice")
        poller = MetricsPoller(str(tmp_path), cluster.cluster_id)
        latest = poller.poll_once()
        assert latest["backlog"] == 6
        assert latest["backlog_by_tenant"] == {"alice": 6}
        http = make_server(poller, ("syndeo_backlog_per_worker",
                                    "syndeo_busy_fraction"))
        threading.Thread(target=http.serve_forever, daemon=True).start()
        try:
            host, port = http.server_address
            with urllib.request.urlopen(
                    f"http://{host}:{port}/metrics", timeout=5) as r:
                flat = json.load(r)
            assert flat["syndeo_backlog_per_worker"] == 6.0
            assert flat["syndeo_busy_fraction"] == 0.0
            # real HPA queries carry a labelSelector query string
            with urllib.request.urlopen(
                    f"http://{host}:{port}/apis/custom.metrics.k8s.io/"
                    f"v1beta1/namespaces/default/pods/%2A/"
                    f"syndeo_backlog_per_worker"
                    f"?labelSelector=app%3Dsyndeo-abc", timeout=5) as r:
                body = json.load(r)
            assert body["kind"] == "MetricValueList"
            assert body["items"][0]["value"] == "6000m"
        finally:
            http.shutdown()
    finally:
        server.shutdown()
        cluster.shutdown()


# ------------------------------------------------- sim: contention scenario

def test_sim_tenant_scenario_fairness():
    """Equal-weight bursty-vs-steady contention in virtual time: the
    fair-share scheduler keeps the dominant-share gap tiny while both are
    backlogged (the benchmark's property, at test scale)."""
    cost = SimCostModel(task_time_s=lambda s: 0.5,
                        result_bytes=lambda s: 100.0, jitter=0.0)
    sim = SimCluster(cost, SchedulerConfig(enable_speculation=False,
                                           heartbeat_timeout=1e9), seed=1)
    sim.add_workers(4)
    sim.register_tenant("steady")
    sim.register_tenant("bursty")
    gaps = []

    def on_tick(now):
        backlog = sim.scheduler.backlog_by_tenant()
        if backlog.get("steady", 0) and backlog.get("bursty", 0):
            s = sim.scheduler.tenant_shares()
            gaps.append(abs(s["steady"] - s["bursty"]))

    placed = sim.run_tenant_scenario(
        {"steady": [(0.1 * i, TaskSpec(fn=None)) for i in range(100)],
         "bursty": [(1.0, TaskSpec(fn=None)) for _ in range(80)]},
        tick_every=0.1, on_tick=on_tick)
    assert gaps, "scenario never contended"
    assert sum(gaps) / len(gaps) < 0.15
    for tenant, pairs in placed.items():
        assert pairs, tenant
        for _, tid in pairs:
            task = sim.scheduler.graph.tasks[tid]
            assert task.state == TaskState.FINISHED
            assert task.spec.tenant_id == tenant
