"""Uniform model interface over the architecture zoo.

build_model(cfg) returns a Model whose members close over the config:
  init_params(key)                      -> params pytree
  loss(params, batch)                   -> (scalar, metrics)     [train]
  prefill(params, batch)                -> (logits, cache)       [serve]
  decode_step(params, cache, batch)     -> (logits, new cache)   [serve]
  init_cache(batch_size, max_len)       -> cache pytree

input_specs(cfg, shape) produces ShapeDtypeStruct stand-ins for every model
input of the given (arch x shape) cell -- the dry-run lowers against these
(no device allocation; weak-type-correct; shardable).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeConfig
from repro.models import dense, hybrid, whisper, xlstm
from repro.models.whisper import ENC_LEN


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init_params: Callable[..., Any]
    loss: Callable[..., Any]
    prefill: Callable[..., Any]
    decode_step: Callable[..., Any]
    init_cache: Callable[..., Any]


def build_model(cfg: ModelConfig, *, n_groups: int = 1,
                window: Optional[int] = None) -> Model:
    if cfg.family in ("dense", "moe", "vlm"):
        return Model(
            cfg=cfg,
            init_params=functools.partial(dense.init_params, cfg=cfg),
            loss=functools.partial(dense.lm_loss, cfg=cfg, n_groups=n_groups),
            prefill=functools.partial(dense.lm_prefill, cfg=cfg,
                                      n_groups=n_groups, window=window),
            decode_step=functools.partial(dense.lm_decode_step, cfg=cfg,
                                          n_groups=n_groups, window=window),
            init_cache=functools.partial(dense.init_cache, cfg),
        )
    if cfg.family == "hybrid":
        return Model(
            cfg=cfg,
            init_params=functools.partial(hybrid.init_params, cfg=cfg),
            loss=functools.partial(hybrid.lm_loss, cfg=cfg),
            prefill=functools.partial(hybrid.lm_prefill, cfg=cfg, window=window),
            decode_step=functools.partial(hybrid.lm_decode_step, cfg=cfg,
                                          window=window),
            init_cache=functools.partial(hybrid.init_cache, cfg, window=window),
        )
    if cfg.family == "ssm":
        return Model(
            cfg=cfg,
            init_params=functools.partial(xlstm.init_params, cfg=cfg),
            loss=functools.partial(xlstm.lm_loss, cfg=cfg),
            prefill=functools.partial(xlstm.lm_prefill, cfg=cfg),
            decode_step=functools.partial(xlstm.lm_decode_step, cfg=cfg),
            init_cache=functools.partial(xlstm.init_cache, cfg),
        )
    if cfg.family == "audio":
        return Model(
            cfg=cfg,
            init_params=functools.partial(whisper.init_params, cfg=cfg),
            loss=functools.partial(whisper.lm_loss, cfg=cfg),
            prefill=functools.partial(whisper.lm_prefill, cfg=cfg),
            decode_step=functools.partial(whisper.lm_decode_step, cfg=cfg),
            init_cache=functools.partial(whisper.init_cache, cfg),
        )
    raise ValueError(f"unknown family {cfg.family!r}")


# ----------------------------------------------------------------------------
# Input specs (dry-run stand-ins)
# ----------------------------------------------------------------------------

def _frontend_specs(cfg: ModelConfig, B: int) -> Dict[str, jax.ShapeDtypeStruct]:
    S = jax.ShapeDtypeStruct
    bf16 = jnp.bfloat16
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.family == "audio":
        out["enc_embeds"] = S((B, ENC_LEN, cfg.d_model), bf16)
    if cfg.family == "vlm":
        out["patch_embeds"] = S((B, cfg.vlm.n_patches, cfg.d_model), bf16)
    return out


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    S = jax.ShapeDtypeStruct
    i32 = jnp.int32
    B, T = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        specs = {"tokens": S((B, T), i32), "targets": S((B, T), i32)}
        specs.update(_frontend_specs(cfg, B))
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": S((B, T), i32)}
        specs.update(_frontend_specs(cfg, B))
        return specs
    if shape.kind == "decode":
        return {"tokens": S((B, 1), i32), "positions": S((B,), i32)}
    raise ValueError(shape.kind)


def cache_specs(cfg: ModelConfig, shape: ShapeConfig,
                window: Optional[int] = None) -> Any:
    """ShapeDtypeStruct pytree for the decode cache of this cell."""
    model = build_model(cfg, window=window)
    B, T = shape.global_batch, shape.seq_len
    if cfg.family == "ssm":
        fn = lambda: model.init_cache(B)
    else:
        fn = lambda: model.init_cache(B, T)
    return jax.eval_shape(fn)


def shape_window(cfg: ModelConfig, shape: ShapeConfig) -> Optional[int]:
    """Long-context cells use the arch's sliding window (if any)."""
    if shape.name == "long_500k":
        return cfg.long_context_window
    return None


def make_batch(cfg: ModelConfig, shape: ShapeConfig, key=None) -> Dict[str, Any]:
    """Concrete random batch matching input_specs (smoke tests / examples)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    specs = input_specs(cfg, shape)
    out = {}
    for name, s in specs.items():
        key, sub = jax.random.split(key)
        if s.dtype == jnp.int32:
            if name == "positions":
                out[name] = jnp.zeros(s.shape, jnp.int32)
            else:
                out[name] = jax.random.randint(sub, s.shape, 0, cfg.vocab_size, jnp.int32)
        else:
            out[name] = jax.random.normal(sub, s.shape, jnp.float32).astype(s.dtype)
    return out
