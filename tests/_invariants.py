"""Global invariant checker for the drain/migration chaos harness.

Every chaos scenario -- kill, drain, partition, dropped commit, expired
ticket, at any point of a two-phase move -- must leave the storage layer
in a state where ALL of the following hold (see tests/README.md):

  1. directory ⊆ reality: every location the directory lists actually
     holds the blob (a phantom location would serve as false drain cover
     and could cost the last real copy),
  2. exactly-one owner per live ref: an object with any live copy has
     exactly one owner, and that owner is one of its locations (a move
     must hand ownership off atomically -- never zero owners, never an
     owner pointing at a node that dropped its copy),
  3. in-flight moves are anchored: a PREPAREd move's source still holds
     the object (an aborted/committed move must not linger),
  4. replica coherence: every location of a ref holds byte-identical
     blob content (a broadcast tree relays copies through consumers, so
     a corrupted relay must be caught here, not at first deserialize),
  5. fetchable-set preservation (opt-in): everything fetchable before a
     *graceful* operation is fetchable after it,
  6. zero hot-producer re-execution (opt-in): drains migrate, they never
     recompute.

Call it after the dust settles (it snapshots under the shard locks but
probes node stores outside them, so a racing mutation could
false-positive). The invariants hold per object regardless of the
store's shard count -- `directory_snapshot` collates all shards.
"""
import math

from repro.core import ObjectRef
from repro.core.metrics import build_cluster_metrics, parse_prometheus


def check_invariants(store, expect_fetchable=None, scheduler=None,
                     expect_zero_reconstructions=False):
    """Assert the global storage invariants; returns the directory
    snapshot ({oid: (locations, owner, refcount)}) for extra checks."""
    snapshot, nodes, moves = store.directory_snapshot()

    for oid, (locs, owner, _rc) in snapshot.items():
        ref = ObjectRef(oid)
        for n in locs:
            node = nodes.get(n)
            assert node is not None, \
                f"{oid}: directory lists unregistered node {n}"
            assert node.has(ref), \
                f"{oid}: directory lists {n} but its store lacks the blob"
        if locs:
            assert owner is not None and owner in locs, \
                f"{oid}: owner {owner!r} is not among locations {locs}"
        # replica coherence: every copy a broadcast/migration landed is
        # byte-identical (spilled copies included -- export_blob restores
        # through the delta-chunk manifest). Stores that cannot export
        # (e.g. a remote proxy without the blob plane) are skipped.
        blobs = []
        for n in locs:
            try:
                blobs.append((n, nodes[n].export_blob(ref)))
            except (KeyError, OSError, AttributeError):
                continue
        if len(blobs) > 1:
            n0, b0 = blobs[0]
            for n, b in blobs[1:]:
                assert b == b0, \
                    f"{oid}: replica on {n} diverges from copy on {n0}"

    for oid, (src, _dst) in moves.items():
        assert oid in snapshot, f"in-flight move for released object {oid}"
        locs, _, _ = snapshot[oid]
        assert src in locs, \
            f"in-flight move of {oid}: source {src} no longer holds it"

    if expect_fetchable is not None:
        fetchable = {oid for oid, (locs, _, _) in snapshot.items() if locs}
        missing = set(expect_fetchable) - fetchable
        assert not missing, f"fetchable set not preserved: lost {missing}"

    if expect_zero_reconstructions:
        assert store.stats["reconstructions"] == 0, \
            "a graceful operation cost lineage reconstructions"
        if scheduler is not None:
            assert scheduler.stats["reconstructed"] == 0, \
                "a hot producer was re-executed"
    return snapshot


# -- metrics conformance: exported telemetry must equal ground truth -----------

# store counters the exporter must pass through 1:1 (directory-side only)
_STORE_COUNTERS = ("moves_started", "moves_committed", "moves_aborted",
                   "relay_fallbacks", "head_relayed_bytes", "replica_gc",
                   "broadcast_rounds", "tree_edges")
# spill-tier counters the exporter sums (store tier + worker-local tiers)
_SPILL_COUNTERS = ("delta_spill_bytes_saved", "promotions")


def check_metrics_conformance(store, scheduler=None, export=None, prom=None,
                              router=None, worker_truth=None):
    """Cross-check every exported metric against the raw internal stats
    it claims to summarize. A metric that drifts from reality is worse
    than no metric (operators page on it, autoscalers scale on it), so
    every chaos scenario ends here: after kills, partitions, drains and
    restarts, telemetry must still be *true*.

      * `export`: the flat `metrics`-op snapshot -- a dict, a callable
        returning one (e.g. a live head's dispatch), or None to build
        one directly from ground truth via `build_cluster_metrics`.
      * `prom`: optional Prometheus text (or callable) -- parsed back
        and held against the same snapshot, so the text exposition path
        cannot silently diverge from the JSON path.
      * `router`: optional serve-plane Router -- its queue-depth/shed
        histograms must agree with its own tick/shed counters.
      * `worker_truth`: optional {wid: counters} captured by
        `run_worker(metrics_truth=...)` at worker exit -- each worker's
        head-side delta aggregate must equal the counters the worker
        actually accrued (the lost-flush regression check).

    Returns the verified flat snapshot."""
    if export is None:
        assert scheduler is not None, \
            "need a scheduler to build the default export"
        export = build_cluster_metrics(
            store, scheduler,
            serve_stats=router.snapshot() if router is not None else None,
            replica_count=len(router.replicas) if router is not None
            else None)
    elif callable(export):
        export = export()
    assert export.get("ok") is True, f"metrics export unhealthy: {export!r}"

    # 1. drain/data-plane counters: straight from store.stats
    for k in _STORE_COUNTERS:
        got, want = export[f"syndeo_{k}"], int(store.stats.get(k, 0))
        assert got == want, \
            f"syndeo_{k}: exported {got} but store.stats says {want}"

    # 2. summed counters: store share + per-worker delta aggregates.
    #    The exported `per_worker` dict is the same aggregate the sums
    #    were computed from, so this also catches a sum computed over a
    #    different (stale) snapshot than the one exported.
    wm = list(export.get("per_worker", {}).values())
    want = int(store.stats.get("batched_moves", 0)) \
        + sum(m.get("batched_moves", 0) for m in wm)
    assert export["syndeo_batched_moves"] == want, \
        f"syndeo_batched_moves: exported " \
        f"{export['syndeo_batched_moves']} != truth {want}"
    spill = store.spill_tier_stats()
    for k in _SPILL_COUNTERS:
        want = spill[k] + sum(m.get(k, 0) for m in wm)
        assert export[f"syndeo_{k}"] == want, \
            f"syndeo_{k}: exported {export[f'syndeo_{k}']} != truth {want}"
    for wire_k, src_k in (("worker_blob_serves", "serves"),
                          ("worker_blob_receives", "receives"),
                          ("worker_served_bytes", "served_bytes"),
                          ("worker_drain_pushed_blobs", "drain_pushed_blobs"),
                          ("worker_drain_pushed_bytes",
                           "drain_pushed_bytes")):
        want = sum(m.get(src_k, 0) for m in wm)
        assert export[f"syndeo_{wire_k}"] == want, \
            f"syndeo_{wire_k}: exported {export[f'syndeo_{wire_k}']} " \
            f"!= worker aggregate {want}"

    # 3. per-link flow gauges == the store's live byte accounting
    want_links = {f"{src}->{dst}": int(v)
                  for (src, dst), v in store.link_snapshot().items()}
    assert export["syndeo_link_bytes"] == want_links, \
        f"syndeo_link_bytes diverges from store.bytes_by_link: " \
        f"{export['syndeo_link_bytes']} != {want_links}"

    # 4. sojourn histograms: per-tenant count == the tenant's finished
    #    counter, total == scheduler.stats['finished'] (both sides only
    #    move in on_task_finished, so any dropped/double observation
    #    breaks this)
    if scheduler is not None:
        soj = export["syndeo_tenant_sojourn_count"]
        for tenant, ts in scheduler.tenants.items():
            got = soj.get(tenant, 0)
            assert got == ts.finished, \
                f"sojourn count for {tenant!r}: {got} != " \
                f"finished counter {ts.finished}"
        total = sum(soj.values())
        assert total == scheduler.stats["finished"], \
            f"total sojourn observations {total} != " \
            f"finished tasks {scheduler.stats['finished']}"
        p50 = export["syndeo_tenant_sojourn_p50_s"]
        p99 = export["syndeo_tenant_sojourn_p99_s"]
        for tenant, c in soj.items():
            if c:
                assert 0.0 < p50[tenant] <= p99[tenant], \
                    f"sojourn quantiles inverted for {tenant!r}"

    # 5. serve plane: the exported admission gauges equal the router's
    #    own counters, and the router's depth/shed histograms move in
    #    lockstep with them (one depth sample per tick, one shed-depth
    #    sample per shed admission)
    if router is not None:
        assert export["syndeo_serve_requests"] == router.stats["requests"], \
            f"syndeo_serve_requests {export['syndeo_serve_requests']} != " \
            f"router requests {router.stats['requests']}"
        assert export["syndeo_serve_shed"] == router.stats["shed"], \
            f"syndeo_serve_shed {export['syndeo_serve_shed']} != " \
            f"router shed {router.stats['shed']}"
        fam = router.metrics.family("syndeo_router_queue_depth")
        depth_count = sum(h.count for h in fam.values())
        assert depth_count == router.stats["ticks"], \
            f"router queue-depth observations {depth_count} != " \
            f"ticks {router.stats['ticks']}"
        fam = router.metrics.family("syndeo_router_shed_depth")
        shed_count = sum(h.count for h in fam.values())
        assert shed_count == router.stats["shed"], \
            f"router shed-depth observations {shed_count} != " \
            f"shed counter {router.stats['shed']}"

    # 6. worker-exit truth: the head's per-worker delta aggregates must
    #    equal the counters each worker actually accrued -- a delta
    #    queued but never flushed (the pre-fix drain bug) fails here
    if worker_truth:
        agg = export.get("per_worker", {})
        for wid, truth in worker_truth.items():
            head_side = agg.get(wid, {})
            for k, v in truth.items():
                if k == "polls":
                    continue
                got = head_side.get(k, 0)
                assert got == v, \
                    f"worker {wid!r} metric {k!r}: head aggregated " \
                    f"{got} but the worker accrued {v} (lost delta?)"
        want_polls = sum(t.get("polls", 0) for t in worker_truth.values())
        got_polls = export.get("syndeo_worker_poll_count", 0)
        assert got_polls == want_polls, \
            f"poll histogram count {got_polls} != " \
            f"polls the workers made {want_polls} (lost histogram delta?)"

    # 7. exposition read-back: the Prometheus text path must agree with
    #    the flat snapshot sample-for-sample
    if prom is not None:
        text = prom() if callable(prom) else prom
        parsed = parse_prometheus(text)
        scalars = {(name, ""): float(v) for name, v in export.items()
                   if name.startswith("syndeo_")
                   and isinstance(v, (int, float))
                   and not isinstance(v, bool)}
        for key, want in scalars.items():
            got = parsed.get(key)
            assert got is not None, f"{key[0]} missing from exposition"
            assert math.isclose(got, want, rel_tol=1e-9, abs_tol=1e-9), \
                f"{key[0]}: exposition says {got}, snapshot says {want}"
    return export
