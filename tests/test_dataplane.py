"""Decentralized data plane: peer-to-peer transfers, transfer tickets,
metadata-only results, the leave handshake, and the bandwidth-aware drain
planner.

The property tests drive random object graphs through BOTH planes and
assert byte-identical fetches; the socket tests run a real head + three
worker threads over TCP and assert zero payload bytes transit the head for
worker-to-worker dependencies."""
import pickle
import random
import threading
import time

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover -- bare container
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import (GlobalObjectStore, NodeStore, ObjectRef,
                        RateLimitExceeded, Scheduler, SchedulerConfig,
                        SecurityError, SimCluster, SimCostModel,
                        SyndeoCluster, TaskSpec, TaskState, TransferTicket,
                        WorkerInfo)
from repro.core.rendezvous import FileRendezvous
from repro.core.security import ADMIN_TENANT, mint_cluster_token
from repro.core.worker import BlobServer, HeadServer, run_worker

TOKEN = mint_cluster_token()


# ----------------------------------------------------------- transfer tickets


def test_ticket_roundtrip_and_bindings():
    t = TransferTicket.grant(TOKEN, "obj1", "w0", "w1", "alice", "get",
                             ttl_s=30.0)
    t.verify(TOKEN, "obj1", "w0", "w1", "get", object_tenant="alice")
    wire = TransferTicket.from_wire(t.to_wire())
    wire.verify(TOKEN, "obj1", "w0", "w1", "get", object_tenant="alice")
    # every binding is inside the MAC
    with pytest.raises(SecurityError):
        t.verify(TOKEN, "obj2", "w0", "w1", "get")          # other object
    with pytest.raises(SecurityError):
        t.verify(TOKEN, "obj1", "w9", "w1", "get")          # other source
    with pytest.raises(SecurityError):
        t.verify(TOKEN, "obj1", "w0", "w9", "get")          # other worker
    with pytest.raises(SecurityError):
        t.verify(TOKEN, "obj1", "w0", "w1", "put")          # other right
    with pytest.raises(SecurityError):
        t.verify("0" * 64, "obj1", "w0", "w1", "get")       # other key
    with pytest.raises(SecurityError, match="cross-tenant"):
        t.verify(TOKEN, "obj1", "w0", "w1", "get", object_tenant="bob")


def test_ticket_expiry_and_relabel():
    t = TransferTicket.grant(TOKEN, "obj1", "w0", "w1", "alice", "get",
                             ttl_s=1.0, now=1000.0)
    t.verify(TOKEN, "obj1", "w0", "w1", "get", object_tenant="alice",
             now=1000.5)
    with pytest.raises(SecurityError, match="expired"):
        t.verify(TOKEN, "obj1", "w0", "w1", "get", object_tenant="alice",
                 now=1002.0)
    # relabeling the tenant (or extending expiry) breaks the MAC
    forged = TransferTicket("obj1", "w0", "w1", "bob", "get",
                            t.expires_at, t.mac)
    with pytest.raises(SecurityError):
        forged.verify(TOKEN, "obj1", "w0", "w1", "get", object_tenant="bob",
                      now=1000.5)
    extended = TransferTicket("obj1", "w0", "w1", "alice", "get",
                              t.expires_at + 3600, t.mac)
    with pytest.raises(SecurityError):
        extended.verify(TOKEN, "obj1", "w0", "w1", "get",
                        object_tenant="alice", now=1002.0)


def test_store_requires_tickets_for_worker_fetches():
    g = GlobalObjectStore()
    g.set_access_guard(TOKEN)
    g.set_transfer_guard(True)
    g.register_node(NodeStore("w0"))
    g.register_node(NodeStore("w1"))
    ref = g.put("w0", {"v": 1}, tenant="alice")
    # no ticket -> refused; head remains trusted
    with pytest.raises(SecurityError, match="ticket"):
        g.fetch("w1", ref)
    g.register_node(NodeStore("head"))
    assert g.get("head", ref) == {"v": 1}
    # the head's mint authorizes exactly this (object, src, dst)
    ticket = g.grant_fetch(ref, "w1", "alice")
    assert ticket is not None and ticket.src == "w0"
    assert g.fetch("w1", ref, ticket=ticket) > 0
    assert "w1" in g.locations(ref)
    # already local: the mint declines (nothing to move)
    assert g.grant_fetch(ref, "w1", "alice") is None


def test_grant_fetch_refuses_cross_tenant_at_mint():
    g = GlobalObjectStore()
    g.set_access_guard(TOKEN)
    g.set_transfer_guard(True)
    g.register_node(NodeStore("w0"))
    g.register_node(NodeStore("w1"))
    ref = g.put("w0", b"secret", tenant="alice")
    with pytest.raises(SecurityError, match="cross-tenant"):
        g.grant_fetch(ref, "w1", "bob")
    # a ticket somebody minted for bob's own scope fails verification
    # against alice's object even if presented
    forged = TransferTicket.grant(TOKEN, ref.id, "w0", "w1", "bob", "get")
    with pytest.raises(SecurityError):
        g.fetch("w1", ref, ticket=forged)
    assert g.locations(ref) == {"w0"}


# ------------------------------------------- metadata-only record() admission


def test_record_registers_without_bytes_and_enforces_quota():
    from repro.core import QuotaExceededError, TenantQuota
    g = GlobalObjectStore()
    g.register_node(NodeStore("w0"))
    g.set_quota("alice", TenantQuota(max_bytes=1000))
    ref, spill = g.record("w0", 600, producer_task="t1", ref_id="obj-t1",
                          tenant="alice")
    assert not spill and ref.size == 600
    assert g.locations(ref) == {"w0"}
    assert g.owner_of(ref) == "w0"
    assert g.tenant_usage("alice")["bytes"] == 600
    with pytest.raises(QuotaExceededError):
        g.record("w0", 600, ref_id="obj-t2", tenant="alice")
    # reject rolled back: usage unchanged, directory clean
    assert g.tenant_usage("alice")["bytes"] == 600
    assert g.locations(ObjectRef("obj-t2")) == set()


def test_record_spill_verdict_returned_to_owner():
    from repro.core import TenantQuota
    g = GlobalObjectStore()
    g.register_node(NodeStore("w0"))
    g.set_quota("alice", TenantQuota(max_bytes=100, on_exceed="spill"))
    _, spill = g.record("w0", 600, ref_id="obj-a", tenant="alice")
    assert spill    # the worker (who holds the bytes) is asked to spill


# ------------------------------------------------- p2p == relay property test


def _value(rng: random.Random, i: int):
    kind = rng.randrange(3)
    if kind == 0:
        return {"i": i, "blob": bytes(rng.getrandbits(8)
                                      for _ in range(rng.randrange(1, 512)))}
    if kind == 1:
        return list(range(i, i + rng.randrange(1, 50)))
    return f"obj-{i}-" + "x" * rng.randrange(200)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 5), st.integers(3, 20))
def test_p2p_fetch_matches_relay_bytes(seed, n_nodes, n_objects):
    """Property: fetching through the ticketed p2p path yields exactly the
    bytes the trusted head-relay path yields, for random object graphs --
    including blobs forced through the spill path."""
    rng = random.Random(seed)
    tok = mint_cluster_token()

    def build(tmp):
        g = GlobalObjectStore()
        g.set_access_guard(tok)
        g.register_node(NodeStore("head", capacity_bytes=1 << 30,
                                  spill_dir=tmp))
        for i in range(n_nodes):
            # tiny capacity on some nodes forces LRU spills mid-graph
            cap = rng.choice([256, 1 << 20])
            g.register_node(NodeStore(f"w{i}", capacity_bytes=cap,
                                      spill_dir=tmp))
        return g

    import tempfile
    with tempfile.TemporaryDirectory() as tmp_a, \
            tempfile.TemporaryDirectory() as tmp_b:
        rng_state = rng.getstate()
        relay = build(tmp_a)
        rng.setstate(rng_state)
        p2p = build(tmp_b)
        p2p.set_transfer_guard(True)
        refs = []
        for i in range(n_objects):
            node = f"w{rng.randrange(n_nodes)}"
            value = _value(rng, i)
            tenant = rng.choice(["alice", "bob"])
            r1 = relay.put(node, value, ref_id=f"o{i}", tenant=tenant)
            r2 = p2p.put(node, value, ref_id=f"o{i}", tenant=tenant)
            assert r1.size == r2.size
            refs.append((r1, tenant))
        for ref, tenant in refs:
            dst = f"w{rng.randrange(n_nodes)}"
            expect = relay.get("head", ref)    # trusted control-plane path
            ticket = p2p.grant_fetch(ref, dst, tenant)
            got = p2p.get(dst, ref, ticket=ticket)
            assert pickle.dumps(got) == pickle.dumps(expect)
            # cross-tenant mint is denied for the other principal
            other = "bob" if tenant == "alice" else "alice"
            dst2 = f"w{(int(dst[1:]) + 1) % n_nodes}"
            if dst2 not in p2p.locations(ref):
                with pytest.raises(SecurityError):
                    p2p.grant_fetch(ref, dst2, other)


# -------------------------------------------------- real sockets, 3 workers


def _mul(a, b):
    return a * b


def _pair(x, y):
    return (x, y)


def _slow():
    time.sleep(1.0)
    return "done"


@pytest.fixture()
def tcp_cluster(tmp_path):
    cluster = SyndeoCluster(rendezvous=FileRendezvous(str(tmp_path)))
    server = HeadServer(cluster)
    server.attach()
    yield cluster, server, str(tmp_path)
    server.shutdown()
    cluster.shutdown()


def _start_workers(rdv_dir, cluster_id, n, max_idle_s=60.0):
    threads = []
    for i in range(n):
        t = threading.Thread(
            target=run_worker,
            args=(rdv_dir, cluster_id, f"tcp-w{i}"),
            kwargs={"max_idle_s": max_idle_s}, daemon=True)
        t.start()
        threads.append(t)
    return threads


def _wait_workers(cluster, n, timeout=20.0):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if sum(1 for w in cluster.scheduler.workers.values()
               if w.alive) >= n:
            return
        time.sleep(0.05)
    raise AssertionError(f"{n} workers did not join")


def test_three_worker_p2p_zero_head_payload_bytes(tcp_cluster):
    """Integration: 3 real socket workers; producers' fat results stay on
    their nodes, consumers pull them peer-to-peer -- the head's control
    socket carries ZERO payload bytes."""
    cluster, server, rdv = tcp_cluster
    _start_workers(rdv, cluster.cluster_id, 3)
    _wait_workers(cluster, 3)
    producers = [cluster.submit(_mul, i, 1000) for i in range(4)]
    assert cluster.wait_all(producers, timeout=60) == [
        i * 1000 for i in range(4)]
    out_refs = [cluster.scheduler.graph.tasks[p.id].output
                for p in producers]
    # the primary copy is owned by the producing worker; the head only
    # gained a *client read* copy when wait_all collected the values
    for ref in out_refs:
        owner = cluster.store.owner_of(ref)
        assert owner is not None and owner.startswith("tcp-")
    consumers = [cluster.submit(_pair, deps=[out_refs[i], out_refs[i + 1]])
                 for i in range(3)]
    got = cluster.wait_all(consumers, timeout=60)
    assert got == [(i * 1000, (i + 1) * 1000) for i in range(3)]
    assert server.head_payload_bytes == 0
    # tickets were actually minted and blobs actually served p2p
    assert cluster.store.stats["records"] >= 4


def test_three_worker_relay_mode_counts_head_bytes(tmp_path):
    """The backward-compat relay plane still works -- and every payload
    byte shows up on the head counter (the p2p contrast)."""
    cluster = SyndeoCluster(rendezvous=FileRendezvous(str(tmp_path)),
                            data_plane="relay")
    server = HeadServer(cluster)
    server.attach()
    try:
        _start_workers(str(tmp_path), cluster.cluster_id, 2)
        _wait_workers(cluster, 2)
        t1 = cluster.submit(_mul, 3, 7)
        assert cluster.get(t1, timeout=60) == 21
        ref = cluster.scheduler.graph.tasks[t1.id].output
        assert "head" in cluster.store.locations(ref)
        t2 = cluster.submit(_pair, 0, deps=[ref])
        assert cluster.get(t2, timeout=60) == (0, 21)
        assert server.head_payload_bytes > 0
    finally:
        server.shutdown()
        cluster.shutdown()


def test_blob_server_rejects_forged_and_expired_tickets(tmp_path):
    """Wire-level denial: a worker's blob server refuses fetches with no
    ticket, an expired ticket, a wrong-worker ticket, and a relabeled
    (forged-tenant) ticket -- and serves the genuine one."""
    from repro.core import TCPTransport
    store = NodeStore("w0", spill_dir=str(tmp_path))
    ref = ObjectRef("objx")
    store.put(ref, {"secret": 42})
    srv = BlobServer(store, TOKEN, tenant_of={"objx": "alice"}.get)
    try:
        def transport(requester):
            return TCPTransport(lambda _n: srv.endpoint, TOKEN, requester)

        good = TransferTicket.grant(TOKEN, "objx", "w0", "w1", "alice",
                                    "get", ttl_s=30)
        value = pickle.loads(transport("w1").fetch("w0", ref, good))
        assert value == {"secret": 42}
        with pytest.raises((SecurityError, KeyError)):
            transport("w1").fetch("w0", ref, None)            # no ticket
        expired = TransferTicket.grant(TOKEN, "objx", "w0", "w1", "alice",
                                       "get", ttl_s=-1.0)
        with pytest.raises(SecurityError):
            transport("w1").fetch("w0", ref, expired)
        with pytest.raises(SecurityError):
            transport("w9").fetch("w0", ref, good)            # other worker
        relabeled = TransferTicket("objx", "w0", "w1", "bob", "get",
                                   good.expires_at, good.mac)
        with pytest.raises(SecurityError):
            transport("w1").fetch("w0", ref, relabeled)
        wrong_key = TransferTicket.grant(mint_cluster_token(), "objx",
                                         "w0", "w1", "alice", "get")
        with pytest.raises(SecurityError):
            transport("w1").fetch("w0", ref, wrong_key)
    finally:
        srv.shutdown()


# ----------------------------------------------------- idle-exit (leave) race


def test_idle_clock_resets_on_completion(tcp_cluster):
    """A worker that just finished a long task must not idle-exit on its
    next empty poll: the idle clock starts at completion."""
    cluster, server, rdv = tcp_cluster
    # max_idle_s shorter than the task runtime: under the old accounting
    # (clock reset at dispatch) the worker would exceed it mid-task
    threads = _start_workers(rdv, cluster.cluster_id, 1, max_idle_s=0.7)
    _wait_workers(cluster, 1)
    t = cluster.submit(_slow)
    assert cluster.get(t, timeout=30) == "done"
    # worker is still serving right after the long task
    t2 = cluster.submit(_mul, 2, 5)
    assert cluster.get(t2, timeout=30) == 10
    del threads


def test_leave_refused_until_sole_blobs_replicated(tcp_cluster):
    """A worker solely holding hot blobs may not idle-exit: the head hands
    back replication pushes; only once a peer holds the copies does the
    exit land -- and the objects stay fetchable."""
    cluster, server, rdv = tcp_cluster
    _start_workers(rdv, cluster.cluster_id, 2, max_idle_s=0.4)
    _wait_workers(cluster, 2)
    producers = [cluster.submit(_mul, i, 11) for i in range(4)]
    # wait on scheduler state WITHOUT collecting values: a client read
    # would replicate the results onto the head and defuse the scenario
    deadline = time.time() + 30
    while time.time() < deadline:
        with cluster._lock:
            states = {cluster.scheduler.graph.tasks[p.id].state
                      for p in producers}
        if states == {TaskState.FINISHED}:
            break
        time.sleep(0.05)
    assert states == {TaskState.FINISHED}
    refs = [cluster.scheduler.graph.tasks[p.id].output for p in producers]
    holders = {n for r in refs for n in cluster.store.locations(r)}
    assert holders and "head" not in holders
    # workers idle out; the leave handshake must replicate before exit
    deadline = time.time() + 30
    while time.time() < deadline and any(
            w.alive for w in cluster.scheduler.workers.values()):
        time.sleep(0.1)
    assert not any(w.alive for w in cluster.scheduler.workers.values())
    for r in refs:   # every hot object survived the exits
        assert cluster.store.locations(r)
        assert cluster.get(r) is not None


# ------------------------------------------------ bandwidth-aware drain plan


def _drain_sim(n_survivors, survivor_cap, n_objects, obj_bytes):
    sim = SimCluster(SimCostModel(task_time_s=lambda s: 0.01, jitter=0.0,
                                  data_plane="p2p",
                                  result_location="worker"),
                     SchedulerConfig(enable_speculation=False,
                                     heartbeat_timeout=1e9))
    victim = sim.add_workers(1, capacity_bytes=1 << 30)[0]
    survivors = sim.add_workers(n_survivors, capacity_bytes=survivor_cap)
    refs = [sim.store.put(victim, bytearray(obj_bytes))
            for _ in range(n_objects)]
    return sim, victim, survivors, refs


def test_drain_planner_respects_capacity_and_spreads():
    sim, victim, survivors, refs = _drain_sim(
        n_survivors=4, survivor_cap=300_000, n_objects=8, obj_bytes=100_000)
    sim.drain_worker_at(victim, 0.0)
    sim.run()
    assert victim not in sim.scheduler.workers
    used_dsts = set()
    for r in refs:
        locs = sim.store.locations(r)
        assert locs, "hot object lost"
        used_dsts |= locs
    for s in survivors:
        node = sim.store._nodes[s]
        assert node.used_bytes <= node.capacity, \
            f"{s} over capacity: {node.used_bytes}"
    # 8 x 100KB into 4 x 300KB: must use at least 3 distinct survivors
    assert len(used_dsts & set(survivors)) >= 3
    assert sim.store.stats["reconstructions"] == 0


def test_drain_planner_overflows_to_head_when_survivors_full():
    sim, victim, survivors, refs = _drain_sim(
        n_survivors=2, survivor_cap=120_000, n_objects=6, obj_bytes=100_000)
    sim.drain_worker_at(victim, 0.0)
    sim.run()
    assert victim not in sim.scheduler.workers
    for r in refs:
        assert sim.store.locations(r), "hot object lost"
    for s in survivors:
        node = sim.store._nodes[s]
        assert node.used_bytes <= node.capacity
    # the overflow went to the head store, not over a survivor's budget
    on_head = sum(1 for r in refs if "head" in sim.store.locations(r))
    assert on_head >= 4


# ------------------------------------------------------- submit rate limits


def test_submit_rate_limit_token_bucket():
    clock = [0.0]
    sched = Scheduler(GlobalObjectStore(), lambda t, w: None,
                      config=SchedulerConfig(enable_speculation=False),
                      clock=lambda: clock[0])
    sched.set_submit_rate("alice", rate_per_s=2.0, burst=3)
    for _ in range(3):          # burst admits
        sched.submit(TaskSpec(fn=None, tenant_id="alice"))
    with pytest.raises(RateLimitExceeded, match="alice"):
        sched.submit(TaskSpec(fn=None, tenant_id="alice"))
    assert sched.stats["rate_limited"] == 1
    # other tenants are unaffected
    sched.submit(TaskSpec(fn=None, tenant_id="bob"))
    # tokens refill with the clock
    clock[0] += 1.0             # +2 tokens
    sched.submit(TaskSpec(fn=None, tenant_id="alice"))
    sched.submit(TaskSpec(fn=None, tenant_id="alice"))
    with pytest.raises(RateLimitExceeded):
        sched.submit(TaskSpec(fn=None, tenant_id="alice"))
    # removing the limit restores unbounded submit
    sched.set_submit_rate("alice", 0)
    for _ in range(10):
        sched.submit(TaskSpec(fn=None, tenant_id="alice"))


def test_cluster_register_tenant_wires_rate_limit():
    with SyndeoCluster() as cluster:
        cluster.register_tenant("alice", submit_rate=1.0, submit_burst=2)
        cluster.add_worker()
        cluster.submit(_mul, 1, 1, tenant_id="alice")
        cluster.submit(_mul, 2, 2, tenant_id="alice")
        with pytest.raises(RateLimitExceeded):
            cluster.submit(_mul, 3, 3, tenant_id="alice")
        # surfaced like a quota reject: nothing half-registered
        assert cluster.scheduler.stats["rate_limited"] == 1


# --------------------------------------------------- per-tenant metrics op


def test_metrics_op_surfaces_tenant_shares_and_quota(tcp_cluster):
    cluster, server, rdv = tcp_cluster
    cluster.register_tenant("alice", quota_bytes=1000)
    cluster.register_tenant("bob")
    cluster.put(b"x" * 400, tenant_id="alice")
    reply = server.dispatch({"op": "metrics"})
    assert reply["ok"]
    assert "alice" in reply["syndeo_tenant_dominant_share"]
    frac = reply["syndeo_tenant_quota_fraction"]["alice"]
    assert 0.4 <= frac <= 0.5
    assert reply["syndeo_tenant_quota_fraction"].get("bob", 0.0) == 0.0


def test_drain_planner_sync_path_respects_capacity():
    """Regression (review): the synchronous migrate path (backends without
    a migrate_fn) lands moves mid-scan -- landed bytes must stay charged
    against the capacity snapshot or one survivor absorbs everything."""
    store = GlobalObjectStore()
    sched = Scheduler(store, lambda t, w: None,
                      config=SchedulerConfig(enable_speculation=False))
    store.register_node(NodeStore("head", capacity_bytes=1 << 30))
    store.register_node(NodeStore("v", capacity_bytes=1 << 30))
    store.register_node(NodeStore("s", capacity_bytes=150))
    sched.add_worker(WorkerInfo("v", {"cpu": 1.0}))
    sched.add_worker(WorkerInfo("s", {"cpu": 1.0}))
    refs = [store.put("v", b"x" * 40) for _ in range(5)]   # hot (refcount 1)
    assert sched.begin_drain("v")
    assert sched.drain_complete("v")
    assert sched.finish_drain("v")
    node_s = store._nodes["s"]
    assert node_s.used_bytes <= node_s.capacity, \
        f"survivor overbooked: {node_s.used_bytes}/{node_s.capacity}"
    for r in refs:
        assert store.locations(r), "hot object lost by the drain"
    assert any("head" in store.locations(r) for r in refs), \
        "overflow should have spilled to the head store"


def test_concurrent_drains_share_capacity_projection():
    """Regression (review): two drains planning against the same tight
    survivor must see each other's in-flight assignments -- their joint
    plan may not overbook it."""
    store = GlobalObjectStore()
    sched = Scheduler(store, lambda t, w: None,
                      config=SchedulerConfig(enable_speculation=False))
    moves = []
    sched.migrate_fn = lambda w, ref, dst: moves.append((w, ref, dst))
    store.register_node(NodeStore("head", capacity_bytes=1 << 30))
    for n in ("v1", "v2", "s"):
        cap = 150 if n == "s" else 1 << 30
        store.register_node(NodeStore(n, capacity_bytes=cap))
        sched.add_worker(WorkerInfo(n, {"cpu": 1.0}))
    blobs = {v: [store.put(v, b"y" * 40) for _ in range(3)]
             for v in ("v1", "v2")}
    assert sched.begin_drain("v1")
    assert sched.begin_drain("v2")    # plans while v1's moves are in flight
    per_dst = {}
    for _w, ref, dst in moves:
        per_dst[dst] = per_dst.get(dst, 0) + ref.size
    cap_s = store._nodes["s"].capacity
    assert per_dst.get("s", 0) <= cap_s, \
        f"joint plan overbooks survivor: {per_dst}"
    assert len(moves) == 6            # every hot blob got a destination
    del blobs


def test_leave_relay_worker_head_migrates_blobs(tcp_cluster):
    """Regression (review): a relay-joined worker never physically holds
    its node store's blobs (they live in the head process), so the leave
    handshake must not assign it pushes it cannot serve -- the head
    migrates head-resident blobs itself and lets the worker go."""
    cluster, server, rdv = tcp_cluster
    joined = server.dispatch({"op": "join", "worker": "tcp-relay0",
                              "resources": {"cpu": 1.0}})
    assert joined["ok"] and joined["data_plane"] == "relay"
    # a blob parked on the worker's head-side store (as a drain migration
    # or replication push would leave it)
    ref = cluster.store.put("tcp-relay0", {"v": 1})
    assert cluster.store.sole_holder(ref, "tcp-relay0")
    left = server.dispatch({"op": "leave", "worker": "tcp-relay0"})
    assert left["exit"] is True, left
    assert "head" in cluster.store.locations(ref)
    assert cluster.store.get("head", ref) == {"v": 1}
