"""syndeo-lint's own tests: the fixture corpus (every rule proven to
fire at exact lines, and to stay quiet on the repaired twin), the
baseline machinery, and the real-tree regression pinning
``src/repro/core`` to zero unsuppressed findings."""
import textwrap
from pathlib import Path

import pytest

from repro.analysis import run_analysis
from repro.analysis.__main__ import main as lint_main
from repro.analysis.baseline import (_parse_toml_subset, apply_baseline,
                                     load_baseline)
from repro.analysis.model import Finding

REPO = Path(__file__).resolve().parents[1]
FIXTURES = Path(__file__).resolve().parent / "analysis_fixtures"


def _findings(name):
    return run_analysis([str(FIXTURES / name)])


# -- fixture corpus: known-bad fires exactly, known-good stays quiet ----

KNOWN_BAD = {
    "lock_bad.py": [("SYN-L001", 14), ("SYN-L001", 19)],
    "lock_order_bad.py": [("SYN-L002", 13)],
    "taint_bad.py": [("SYN-A001", 11)],
    "verify_bad.py": [("SYN-A002", 14)],
    "nonce_bad.py": [("SYN-A003", 6)],
    "wire_bad.py": [("SYN-W001", 28), ("SYN-W002", 12),
                    ("SYN-W003", 13)],
    "wire_batch_bad.py": [("SYN-W001", 28), ("SYN-W002", 13)],
    "wire_blobs_bad.py": [("SYN-W001", 35), ("SYN-W002", 18)],
    "wire_actor_bad.py": [("SYN-W001", 28), ("SYN-W002", 17),
                          ("SYN-W003", 15)],
    # metric-delta pass: W001 fires once per send site of the unfolded
    # "hists" payload (exit flush AND queued batch sub-op)
    "wire_metrics_bad.py": [("SYN-W001", 44), ("SYN-W001", 50),
                            ("SYN-W002", 27)],
}

KNOWN_GOOD = ["lock_good.py", "lock_order_good.py", "taint_good.py",
              "verify_good.py", "nonce_good.py", "wire_good.py",
              "wire_batch_good.py", "wire_blobs_good.py",
              "wire_actor_good.py", "wire_metrics_good.py"]


@pytest.mark.parametrize("name,expected", sorted(KNOWN_BAD.items()))
def test_known_bad_fires_exact_rules_and_lines(name, expected):
    got = sorted((f.rule, f.line) for f in _findings(name))
    assert got == sorted(expected)


@pytest.mark.parametrize("name", KNOWN_GOOD)
def test_known_good_is_clean(name):
    assert _findings(name) == []


def test_findings_carry_function_and_message():
    by_line = {f.line: f for f in _findings("lock_bad.py")}
    direct = by_line[14]
    assert direct.function == "Cache.refresh"
    assert "Cache._lock" in direct.message
    transitive = [f for f in _findings("lock_bad.py")
                  if f.function == "Cache.tick"]
    assert transitive and "time.sleep" in transitive[0].message


def test_transitive_chain_in_message():
    (f,) = [x for x in _findings("lock_bad.py") if x.line == 19]
    assert "via" in f.message  # witness chain, not a bare verdict


def test_lock_order_cycle_names_both_locks():
    (f,) = _findings("lock_order_bad.py")
    assert "Ledger._lock" in f.message and "Mirror._lock" in f.message


def test_render_format_is_clickable():
    (f,) = _findings("nonce_bad.py")
    assert f.render().startswith(f"{f.file}:{f.line}: SYN-A003 ")


# -- baseline machinery -------------------------------------------------


def _finding(rule="SYN-L001", file="src/repro/core/worker.py", line=1,
             function="HeadServer.dispatch", message="call x() blocks"):
    return Finding(rule, file, line, function, message)


def test_baseline_matches_on_rule_file_function_and_match():
    entries = [{"rule": "SYN-L001", "file": "worker.py",
                "function": "HeadServer.dispatch", "match": "x()",
                "reason": "documented"}]
    unsup, sup, unused = apply_baseline([_finding()], entries)
    assert not unsup and len(sup) == 1 and not unused


def test_baseline_does_not_match_other_function_or_rule():
    entries = [{"rule": "SYN-L001", "file": "worker.py",
                "function": "BlobServer._handle", "reason": "r"}]
    unsup, _, unused = apply_baseline([_finding()], entries)
    assert len(unsup) == 1 and len(unused) == 1


def test_baseline_loader_rejects_missing_reason(tmp_path):
    p = tmp_path / "b.toml"
    p.write_text('[[suppress]]\nrule = "SYN-L001"\nfile = "x.py"\n')
    with pytest.raises(ValueError, match="reason"):
        load_baseline(str(p))


def test_toml_subset_parser_round_trips_the_shape():
    data = _parse_toml_subset(textwrap.dedent('''
        # comment
        [[suppress]]
        rule = "SYN-A002"
        file = "worker.py"
        reason = "verified in _handle() before the \\"blob\\" frame"

        [[suppress]]
        rule = "SYN-L001"
        file = "cluster.py"
        reason = "bounded"
    '''))
    assert [e["rule"] for e in data["suppress"]] == ["SYN-A002",
                                                     "SYN-L001"]
    assert '"blob"' in data["suppress"][0]["reason"]


def test_repo_baseline_parses_with_fallback_parser():
    # CI (3.11) parses with tomllib; this keeps the 3.10 fallback honest
    text = (REPO / "analysis" / "baseline.toml").read_text()
    data = _parse_toml_subset(text)
    assert all(e.get("reason") for e in data["suppress"])


# -- CLI ----------------------------------------------------------------


def test_cli_exit_codes(capsys):
    assert lint_main([str(FIXTURES / "lock_bad.py"),
                      "--no-baseline"]) == 1
    assert "SYN-L001" in capsys.readouterr().out
    assert lint_main([str(FIXTURES / "lock_good.py"),
                      "--no-baseline"]) == 0


# -- real-tree regression ----------------------------------------------


def test_real_tree_has_zero_unsuppressed_findings():
    """The CI gate: src/repro/core is clean modulo the reviewed
    baseline, and the baseline carries no stale entries."""
    findings = run_analysis([str(REPO / "src" / "repro" / "core")])
    entries = load_baseline(str(REPO / "analysis" / "baseline.toml"))
    unsuppressed, suppressed, unused = apply_baseline(findings, entries)
    assert unsuppressed == [], "\n".join(f.render()
                                         for f in unsuppressed)
    assert unused == [], f"stale baseline entries: {unused}"
    assert suppressed, "baseline expected to cover documented exceptions"


def test_real_tree_wire_protocol_is_symmetric():
    """No unsuppressed W-rule findings: every op sent in-tree has a
    handler and every required field is sent."""
    findings = run_analysis([str(REPO / "src" / "repro" / "core")])
    assert [f for f in findings if f.rule.startswith("SYN-W")] == []
