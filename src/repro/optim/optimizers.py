"""Optimizers in pure JAX: AdamW (fp32 moments, ZeRO-1 shardable) and
Adafactor (factored second moment -- the only optimizer whose state fits a
480B-param model on a 256x16GB pod; see DESIGN.md).

Interface (functional):
  opt = make_optimizer(cfg)            # from ModelConfig.optimizer
  state = opt.init(params)
  new_params, new_state, stats = opt.update(params, grads, state, lr)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Any], Any]
    update: Callable[..., Any]


# ----------------------------------------------------------------------------
# AdamW
# ----------------------------------------------------------------------------

def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, F32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(params, grads, state, lr):
        step = state["step"] + 1
        t = step.astype(F32)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(p, g, m, v):
            g = g.astype(F32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mh = m / bc1
            vh = v / bc2
            delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(F32)
            return (p.astype(F32) - lr * delta).astype(p.dtype), m, v

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        gnorm = global_norm(grads)
        return new_p, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gnorm}

    return Optimizer("adamw", init, update)


# ----------------------------------------------------------------------------
# Adafactor (Shazeer & Stern 2018), factored second moments, no first moment
# ----------------------------------------------------------------------------

def adafactor(eps1: float = 1e-30, eps2: float = 1e-3, clip: float = 1.0,
              decay_pow: float = 0.8, weight_decay: float = 0.0) -> Optimizer:
    def _factored(shape) -> bool:
        return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1

    def init(params):
        def per(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], F32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], F32)}
            return {"v": jnp.zeros(p.shape, F32)}
        return {"s": jax.tree.map(per, params,
                                  is_leaf=lambda x: hasattr(x, "shape")),
                "step": jnp.zeros((), jnp.int32)}

    def update(params, grads, state, lr):
        step = state["step"] + 1
        t = step.astype(F32)
        beta = 1.0 - t ** (-decay_pow)

        def upd_core(p, g, s):
            g = g.astype(F32)
            g2 = jnp.square(g) + eps1
            if _factored(p.shape):
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.mean(vr, axis=-1, keepdims=True)
                u = g * jax.lax.rsqrt(vr / jnp.maximum(denom, eps1))[..., None] \
                    * jax.lax.rsqrt(vc)[..., None, :]
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v)
                new_s = {"v": v}
            # RMS clipping
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + eps1)
            u = u / jnp.maximum(1.0, rms_u / clip)
            scale = jnp.maximum(eps2, jnp.sqrt(jnp.mean(jnp.square(p.astype(F32)))))
            delta = lr * scale * u
            if weight_decay:
                delta = delta + lr * weight_decay * p.astype(F32)
            return (p.astype(F32) - delta).astype(p.dtype), new_s

        def upd(p, g, s):
            # Stacked-layer params (leading scan dim): update layer by layer
            # so the fp32 intermediates (u, g2) materialize at 1/L size --
            # a 480B-param model's update transients drop from ~8 GiB to
            # ~0.25 GiB per device. Semantically exact: the stack is L
            # independent tensors, and clipping/scale are per-tensor anyway.
            if p.ndim >= 3 and _factored(p.shape) and p.shape[0] <= 1024:
                return jax.lax.map(lambda a: upd_core(*a), (p, g, s))
            return upd_core(p, g, s)

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state["s"])
        out = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_s = tdef.unflatten([o[1] for o in out])
        return new_p, {"s": new_s, "step": step}, {"grad_norm": global_norm(grads)}

    return Optimizer("adafactor", init, update)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(F32))) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda l: (l.astype(F32) * scale).astype(l.dtype), tree), n


def make_optimizer(name: str, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(**kw)
    if name == "adafactor":
        return adafactor(**kw)
    raise ValueError(name)


# ----------------------------------------------------------------------------
# LR schedules
# ----------------------------------------------------------------------------

def warmup_cosine(base_lr: float, warmup: int, total: int, min_frac: float = 0.1):
    def lr(step):
        step = step.astype(F32) if hasattr(step, "astype") else float(step)
        w = jnp.minimum(1.0, step / jnp.maximum(warmup, 1))
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * w * cos
    return lr
