"""known-good: both paths take Ledger's lock before Mirror's."""
import threading


class Ledger:
    def __init__(self, peer: "Mirror"):
        self._lock = threading.Lock()
        self.peer = peer
        self.rows = {}

    def post(self, key, value):
        with self._lock:
            with self.peer._lock:             # Ledger -> Mirror
                self.peer.rows[key] = value


class Mirror:
    def __init__(self, peer: "Ledger"):
        self._lock = threading.Lock()
        self.peer = peer
        self.rows = {}

    def sync(self, key):
        with self.peer._lock:                 # Ledger first, same order
            with self._lock:
                return self.rows.get(key)
