"""Custom-metrics adapter: the bridge between the Syndeo scheduler and a
Kubernetes HorizontalPodAutoscaler.

The K8s backend renders an HPA that scales the worker Deployment on the
scheduler's *own* demand signals (READY+PENDING backlog per worker, busy
fraction) instead of pod CPU -- the declarative replacement for the old
imperative `kubectl scale` script. This process closes that loop: it polls
the head's HMAC-sealed `metrics` op over the same rendezvous + TCP protocol
the workers use, and republishes the values in the
`custom.metrics.k8s.io/v1beta1` shape the HPA consumes.

Kept deliberately dependency-free (stdlib http.server): in a real cluster
it runs behind the APIService registration the backend renders; in this
repo the subprocess test drives it against a live HeadServer.
"""
from __future__ import annotations

import argparse
import json
import ssl
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict
from urllib.parse import urlsplit

from repro.core.rendezvous import FileRendezvous
from repro.core.security import NonceCache

DEFAULT_METRICS = ("syndeo_backlog_per_worker", "syndeo_busy_fraction",
                   "syndeo_tenant_dominant_share",
                   "syndeo_tenant_quota_fraction",
                   # drain-plane health counters (ROADMAP: previously
                   # tracked by the store but unreported): dashboards
                   # alert on aborted moves / relay downgrades, and the
                   # p2p-vs-relay benchmark reads head_relayed_bytes
                   "syndeo_moves_aborted", "syndeo_relay_fallbacks",
                   "syndeo_head_relayed_bytes", "syndeo_replica_gc",
                   # data-plane throughput layer: broadcast-tree fan-out,
                   # multi-blob move frames, spill-tier efficiency --
                   # dashboards watch bytes saved / promotions to size
                   # spill dirs, and tree_edges/rounds to spot fan-out
                   # regressions before the serving plane multiplies them
                   "syndeo_broadcast_rounds", "syndeo_tree_edges",
                   "syndeo_batched_moves", "syndeo_delta_spill_bytes_saved",
                   "syndeo_promotions",
                   # serving plane: router-fed admission counters + tail
                   # latency and the live replica count -- the signals an
                   # SLO-driven replica HPA scales on (paper Sec. IV's
                   # K8s priority/elasticity story applied to serving)
                   "syndeo_serve_requests", "syndeo_serve_shed",
                   "syndeo_serve_p99_ms", "syndeo_replica_count",
                   # observability plane: per-tenant submit->result
                   # sojourn percentiles (bucket-bounded histogram
                   # quantiles), per-link byte flows, and worker poll
                   # round-trip tails -- dashboards and latency-SLO HPAs
                   # read these; the chaos conformance checker holds
                   # them against scheduler/store ground truth
                   "syndeo_tenant_sojourn_p50_s",
                   "syndeo_tenant_sojourn_p99_s",
                   "syndeo_tenant_sojourn_count",
                   "syndeo_link_bytes", "syndeo_moves_committed",
                   "syndeo_worker_poll_p99_s")


class MetricsPoller:
    """Background thread keeping the latest head `metrics` reply."""

    def __init__(self, rendezvous_dir: str, cluster_id: str,
                 poll_every_s: float = 2.0):
        self.rendezvous_dir = rendezvous_dir
        self.cluster_id = cluster_id
        self.poll_every_s = poll_every_s
        self.latest: Dict[str, object] = {}
        self._nonces = NonceCache()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="syndeo-metrics-poller")

    def start(self):
        self._thread.start()

    def stop(self):
        self._stop.set()

    def poll_once(self) -> Dict[str, object]:
        from repro.core.worker import _request
        ep = FileRendezvous(self.rendezvous_dir).wait(self.cluster_id,
                                                      timeout=30.0)
        self.latest = _request(ep.host, ep.port, ep.token,
                               {"op": "metrics"}, nonce_cache=self._nonces)
        return self.latest

    def poll_text(self) -> str:
        """Fetch the head's Prometheus text exposition (`metrics_text`
        op) -- served on demand at /metrics/prometheus, so a scrape
        always sees a fresh snapshot."""
        from repro.core.worker import _request
        ep = FileRendezvous(self.rendezvous_dir).wait(self.cluster_id,
                                                      timeout=30.0)
        reply = _request(ep.host, ep.port, ep.token,
                         {"op": "metrics_text"}, nonce_cache=self._nonces)
        return str(reply.get("text", ""))

    def _loop(self):
        while not self._stop.is_set():
            try:
                self.poll_once()
            except Exception:  # noqa: BLE001 -- a flaky head is not fatal
                pass
            time.sleep(self.poll_every_s)


def _metric_item(name: str, value: float) -> Dict[str, object]:
    # HPA Pods-metrics consume milli-quantities; serve both shapes
    return {"metricName": name,
            "value": f"{int(round(value * 1000))}m",
            "valueFloat": value,
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime())}


def make_server(poller: MetricsPoller, metrics: tuple, host: str = "127.0.0.1",
                port: int = 0) -> ThreadingHTTPServer:
    """HTTP face: /healthz, /metrics (flat JSON), and the
    custom.metrics.k8s.io/v1beta1 resource paths the HPA queries."""

    class Handler(BaseHTTPRequestHandler):
        def _json(self, code: int, payload: Dict[str, object]):
            blob = json.dumps(payload).encode()
            self.send_response(code)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(blob)))
            self.end_headers()
            self.wfile.write(blob)

        def do_GET(self):  # noqa: N802 -- BaseHTTPRequestHandler API
            latest = poller.latest
            # HPA queries carry ?labelSelector=... -- route on the bare path
            path = urlsplit(self.path).path
            if path == "/healthz":
                self._json(200 if latest else 503,
                           {"ok": bool(latest)})
                return
            if path == "/metrics":
                self._json(200, {m: latest.get(m, 0.0) for m in metrics})
                return
            if path == "/metrics/prometheus":
                try:
                    blob = poller.poll_text().encode()
                except Exception as e:  # noqa: BLE001 -- flaky head
                    self._json(503, {"error": str(e)})
                    return
                self.send_response(200)
                self.send_header("Content-Type",
                                 "text/plain; version=0.0.4")
                self.send_header("Content-Length", str(len(blob)))
                self.end_headers()
                self.wfile.write(blob)
                return
            if path.startswith("/apis/custom.metrics.k8s.io/v1beta1"):
                name = path.rstrip("/").rsplit("/", 1)[-1]
                if name in metrics:
                    value = latest.get(name, 0.0)
                    if isinstance(value, dict):
                        # per-tenant metric (dominant share, quota
                        # pressure): one item per tenant, named so an HPA
                        # or dashboard can select a single principal
                        items = [dict(_metric_item(name, float(v)),
                                      describedObject={
                                          "kind": "Tenant",
                                          "apiVersion": "syndeo/v1",
                                          "name": tenant})
                                 for tenant, v in sorted(value.items())]
                    else:
                        items = [_metric_item(name, float(value))]
                    self._json(200, {
                        "kind": "MetricValueList",
                        "apiVersion": "custom.metrics.k8s.io/v1beta1",
                        "items": items})
                    return
                self._json(200, {
                    "kind": "APIResourceList",
                    "apiVersion": "custom.metrics.k8s.io/v1beta1",
                    "resources": [{"name": m, "namespaced": True}
                                  for m in metrics]})
                return
            self._json(404, {"error": f"unknown path {path}"})

        def log_message(self, *args):  # quiet
            pass

    return ThreadingHTTPServer((host, port), Handler)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rendezvous", required=True)
    ap.add_argument("--cluster-id", required=True)
    ap.add_argument("--metrics", default=",".join(DEFAULT_METRICS))
    ap.add_argument("--port", type=int, default=6443)
    ap.add_argument("--poll-every-s", type=float, default=2.0)
    # API aggregation always connects over TLS (insecureSkipTLSVerify only
    # skips *validation*): in-cluster the adapter must serve HTTPS with the
    # mounted serving cert, or the APIService goes Unavailable
    ap.add_argument("--tls-cert", default="")
    ap.add_argument("--tls-key", default="")
    args = ap.parse_args()
    poller = MetricsPoller(args.rendezvous, args.cluster_id,
                           args.poll_every_s)
    poller.poll_once()
    poller.start()
    server = make_server(poller, tuple(args.metrics.split(",")),
                         host="0.0.0.0", port=args.port)
    if args.tls_cert and args.tls_key:
        ctx = ssl.SSLContext(ssl.PROTOCOL_TLS_SERVER)
        ctx.load_cert_chain(args.tls_cert, args.tls_key)
        server.socket = ctx.wrap_socket(server.socket, server_side=True)
    print(f"metrics adapter up on port {server.server_address[1]}",
          flush=True)
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        poller.stop()
        server.shutdown()


if __name__ == "__main__":
    main()
