"""Slurm backend: renders the sbatch script that hosts a Syndeo cluster
inside a Slurm allocation (the paper's headline deployment).

The script implements the bring-up protocol exactly as §III-D describes:
node 0 starts the containerized head and writes IP:port to the shared
filesystem; every other node polls that file and joins as a worker."""
from __future__ import annotations

from typing import Dict, List

from repro.core.backends.base import AllocationRequest, Backend
from repro.core.containers import apptainer_definition, apptainer_run_command


class SlurmBackend(Backend):
    name = "slurm"
    supports_elastic = True

    def render_artifacts(self, req: AllocationRequest,
                         cluster_id: str) -> Dict[str, str]:
        head_cmd = apptainer_run_command(self.container, role="head",
                                         rendezvous_dir=req.shared_dir,
                                         cluster_id=cluster_id)
        # Syndeo worker id == Slurm NodeName: workers join under $(hostname)
        # and record the mapping under the rendezvous, so scale-down can
        # resolve the scheduler's worker ids back to drainable hosts.
        # --blob-host: the p2p blob server must advertise the node's
        # fabric address, not the 127.0.0.1 default, or peers dial their
        # own loopback
        worker_cmd = (apptainer_run_command(self.container, role="worker",
                                            rendezvous_dir=req.shared_dir,
                                            cluster_id=cluster_id)
                      + ' --worker-id "$(hostname)"'
                      + ' --blob-host "$(hostname -i | cut -d\' \' -f1)"')
        record_host = (f'echo "$(hostname)" > '
                       f'"{req.shared_dir}/rdv/workers/$(hostname).host"')
        reservation = (f"#SBATCH --reservation={req.reservation}\n"
                       if req.reservation else "")
        sbatch = f"""\
#!/bin/bash
#SBATCH --job-name=syndeo-{cluster_id}
#SBATCH --nodes={req.nodes}
#SBATCH --ntasks-per-node=1
#SBATCH --cpus-per-task={req.cpus_per_node}
#SBATCH --time={req.walltime}
#SBATCH --partition={req.partition}
{reservation}#SBATCH --output={req.shared_dir}/logs/%j_%n.out

set -euo pipefail
mkdir -p {req.shared_dir}/logs {req.shared_dir}/rdv {req.shared_dir}/rdv/workers
{record_host}

# ---- phase 1: every node already has a copy of the container ----
# (image staged to {req.shared_dir} before submission; immutable at runtime)

NODELIST=$(scontrol show hostnames "$SLURM_JOB_NODELIST")
HEAD_NODE=$(echo "$NODELIST" | head -n1)

if [ "$(hostname)" = "$HEAD_NODE" ]; then
    # ---- phase 2: start the Ray-equivalent head; endpoint -> shared FS ----
    {head_cmd} &
    HEAD_PID=$!
else
    # ---- phase 3: workers poll the shared FS for the head endpoint ----
    {worker_cmd} &
    HEAD_PID=$!
fi

# ---- phase 4: the cluster accepts jobs at the head ----
wait $HEAD_PID
"""
        srun_variant = f"""\
#!/bin/bash
# Alternative launcher: one srun step per role (heterogeneous jobs).
srun --nodes=1 --ntasks=1 -w "$HEAD_NODE" {head_cmd} &
srun --nodes={req.nodes - 1} --ntasks={req.nodes - 1} {worker_cmd} &
wait
"""
        return {
            "syndeo.def": apptainer_definition(self.container),
            f"submit_{cluster_id}.sbatch": sbatch,
            f"srun_steps_{cluster_id}.sh": srun_variant,
        }

    # -- elasticity: a worker-only sbatch joins the live rendezvous ------------

    def provision_workers(self, req: AllocationRequest, cluster_id: str,
                          count: int) -> Dict[str, str]:
        worker_cmd = (apptainer_run_command(self.container, role="worker",
                                            rendezvous_dir=req.shared_dir,
                                            cluster_id=cluster_id)
                      + ' --worker-id "$(hostname)"'
                      + ' --blob-host "$(hostname -i | cut -d\' \' -f1)"')
        # guaranteed gang growth instead of hoping the partition has free
        # nodes: --dependency=singleton serializes scale-up jobs (all share
        # this job name), so bursts of autoscaler decisions queue in order
        # rather than racing each other for the same nodes, and an optional
        # standing --reservation pins the capacity the growth draws from.
        reservation = (f"#SBATCH --reservation={req.reservation}\n"
                       if req.reservation else "")
        scale_up = f"""\
#!/bin/bash
#SBATCH --job-name=syndeo-{cluster_id}-scaleup
#SBATCH --nodes={count}
#SBATCH --ntasks-per-node=1
#SBATCH --cpus-per-task={req.cpus_per_node}
#SBATCH --time={req.walltime}
#SBATCH --partition={req.partition}
#SBATCH --dependency=singleton
{reservation}#SBATCH --output={req.shared_dir}/logs/%j_%n.out

set -euo pipefail
# elastic scale-up: every node of this job joins the *existing* head via
# the shared-FS rendezvous (bring-up phase 3 only -- the head stays put),
# registering under its hostname so scale-down can find it again.
mkdir -p {req.shared_dir}/rdv/workers
echo "$(hostname)" > "{req.shared_dir}/rdv/workers/$(hostname).host"
{worker_cmd} &
wait
"""
        return {f"scale_up_{cluster_id}_{count}.sbatch": scale_up}

    def release_workers(self, req: AllocationRequest, cluster_id: str,
                        worker_ids: List[str],
                        drain_deadline_s: float = 0.0) -> Dict[str, str]:
        # Reconciliation: the scheduler names workers by *Syndeo worker id*;
        # Slurm drains by *NodeName*. Workers record id -> hostname under
        # the rendezvous at join (worker id is the hostname for nodes we
        # launched, but the mapping file is authoritative for any id), so
        # the rendered artifact resolves each id before touching Slurm --
        # it never drains the wrong host.
        resolves = "\n".join(
            f'HOSTS="$HOSTS,$(cat "$MAP/{wid}.host" 2>/dev/null '
            f'|| echo "{wid}")"'
            for wid in worker_ids)
        wait_step = (f"sleep {int(drain_deadline_s)}"
                     if drain_deadline_s > 0 else
                     ": # no drain grace requested (workers already drained)")
        scale_down = f"""\
#!/bin/bash
set -euo pipefail
# graceful scale-down: resolve Syndeo worker ids -> Slurm hostnames via the
# rendezvous mapping, mark those nodes DRAIN (no new Slurm work lands),
# give in-flight processes the drain grace, then cancel only the scale-up
# jobs running *on those hosts* (batches on other nodes keep running).
MAP={req.shared_dir}/rdv/workers
HOSTS=""
{resolves}
HOSTS=${{HOSTS#,}}
for HOST in ${{HOSTS//,/ }}; do
  scontrol update NodeName=$HOST State=DRAIN \\
    Reason="syndeo-{cluster_id} drained scale-down"
done
{wait_step}
scancel --name=syndeo-{cluster_id}-scaleup --nodelist=$HOSTS || true
"""
        return {f"scale_down_{cluster_id}.sh": scale_down}
