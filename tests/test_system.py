"""End-to-end behaviour tests of the Syndeo runtime (paper §III-D phases)."""
import time

import pytest

from repro.core import (ContainerSpec, SchedulerConfig, SecurityError,
                        SyndeoCluster, TaskState, UnprivilegedProfile)


def _mul(a, b):
    return a * b


def _add(x, y):
    return x + y


@pytest.fixture()
def cluster():
    c = SyndeoCluster()
    for _ in range(4):
        c.add_worker()
    yield c
    c.shutdown()


def test_phase_bringup_and_simple_task(cluster):
    t = cluster.submit(_mul, 6, 7)
    assert cluster.get(t) == 42


def test_dependency_driven_execution(cluster):
    """A task starts only when its data dependencies exist (paper Fig. 1)."""
    a = cluster.submit(_mul, 2, 3)
    ra = cluster.get(a)
    ref = cluster.scheduler.graph.tasks[a.id].output
    b = cluster.submit(_add, 10, deps=[ref])   # consumes a's artifact
    assert cluster.get(b) == 16


def test_many_tasks_all_workers(cluster):
    tasks = [cluster.submit(_mul, i, 2) for i in range(40)]
    results = cluster.wait_all(tasks)
    assert results == [i * 2 for i in range(40)]
    used = {cluster.scheduler.graph.tasks[t.id].worker for t in tasks}
    assert len(used) > 1, "work should spread across workers"


def test_task_error_retries_then_fails(cluster):
    def boom():
        raise ValueError("kaboom")
    t = cluster.submit(boom, max_retries=1)
    with pytest.raises(RuntimeError, match="kaboom"):
        cluster.get(t, timeout=30)
    assert cluster.scheduler.graph.tasks[t.id].state == TaskState.FAILED


def test_worker_removal_requeues_work(cluster):
    """Elasticity: removing a worker mid-flight must not lose tasks."""
    def slowish(x):
        time.sleep(0.05)
        return x
    tasks = [cluster.submit(slowish, i) for i in range(20)]
    victim = next(iter(cluster._queues))
    cluster.remove_worker(victim)
    assert cluster.wait_all(tasks, timeout=60) == list(range(20))


def test_worker_join_after_submit():
    """Workers may join late via the rendezvous (phase 3 is elastic)."""
    c = SyndeoCluster()
    t = c.submit(_mul, 3, 3)
    time.sleep(0.05)
    c.add_worker()
    assert c.get(t) == 9
    c.shutdown()


def test_placement_group_binding(cluster):
    ok = cluster.create_placement_group("pg0", [{"cpu": 1.0}] * 2,
                                        strategy="STRICT_SPREAD")
    assert ok
    binding = cluster.scheduler.placement_binding("pg0")
    assert len(set(binding.values())) == 2
    t0 = cluster.submit(_mul, 1, 1, placement_group="pg0", bundle_index=0)
    t1 = cluster.submit(_mul, 2, 2, placement_group="pg0", bundle_index=1)
    cluster.wait_all([t0, t1])
    assert cluster.scheduler.graph.tasks[t0.id].worker == binding[0]
    assert cluster.scheduler.graph.tasks[t1.id].worker == binding[1]


def test_unprivileged_profile_refuses_root(monkeypatch):
    import os
    monkeypatch.setattr(os, "geteuid", lambda: 0, raising=False)
    with pytest.raises(SecurityError, match="root"):
        UnprivilegedProfile(allow_root=False).enforce()


def test_object_put_get_roundtrip(cluster):
    import numpy as np
    arr = np.arange(1000, dtype=np.float32)
    ref = cluster.put(arr)
    out = cluster.get(ref)
    assert (out == arr).all()
