"""whisper-tiny  [arXiv:2212.04356]
4L d_model=384 6H d_ff=1536 vocab=51865, enc-dec. Conv frontend is a STUB:
input_specs() provides precomputed frame embeddings (batch, T_enc, d_model).
6 heads < model-axis 16 => attention is replicated over `model`, FFN sharded."""
from repro.configs.base import ModelConfig, EncDecConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,                    # decoder layers
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    encdec=EncDecConfig(n_enc_layers=4),
)

SMOKE = ModelConfig(
    name="whisper-smoke",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    encdec=EncDecConfig(n_enc_layers=2),
)
