"""Global Object Store -- the Syndeo/Ray data plane.

Jobs get their data dependencies from the store and push artifacts back to
it (paper Fig. 1). This implementation provides:

  * ref-counted objects with owner tracking (who holds a copy),
  * LRU spill-to-disk when a node store exceeds its capacity,
  * lineage: every object remembers the task that produced it, so the
    scheduler can *reconstruct* objects lost to node failures by
    re-executing the producing task (Ray-style fault tolerance),
  * capability-scoped access (security.py tokens) -- multi-tenant safety.

Payloads are arbitrary picklable python objects / numpy arrays. On a real
TPU cluster large tensors move as sharded checkpoint files instead; the
store then carries references (paths + manifests), which is exactly how the
paper's shared-filesystem rendezvous behaves.

Drain / migration
-----------------

When the scheduler retires a worker gracefully (DRAINING lifecycle state,
`scheduler.begin_drain`), objects whose *only* copy lives on the retiring
node are **migrated** to a survivor instead of being dropped and later
rebuilt by lineage re-execution:

  * `objects_on(node)` enumerates directory entries held on a node and
    whether the node is the sole holder -- the scheduler's migration
    planner reads this to decide what must move,
  * `migrate(ref, src, dst)` copies the raw blob between node stores
    without a pickle round-trip, records the new location, drops the old
    one, and **hands off ownership** if the source owned the object; the
    move is capability-checked when the cluster installs a migration
    capability (`set_migration_guard`), so a tenant cannot exfiltrate
    another tenant's objects by draining a shared node,
  * after migration `unregister_node(src)` loses nothing: every hot
    object is served from a survivor, so no lineage reconstruction fires
    (the drain-vs-drop benchmark and the fault-tolerance property tests
    assert exactly this).

Cold objects (zero refcount, not depended on) are simply dropped -- the
drain is then provably no worse than recompute: it never re-executes a
producer for a hot object, and never copies garbage.

Multi-tenancy
-------------

Every directory entry carries the *tenant* that put it. Tenant isolation
and accounting are layered on top of the existing machinery:

  * guarded access: once the head installs the cluster token
    (`set_access_guard`), a `get`/`put`/`migrate` that presents a
    Capability has it verified against the object's tenant -- tenant A's
    capability raises SecurityError on tenant B's objects, including when
    a drain tries to migrate them with a tenant-scoped guard,
  * quotas: `set_quota(tenant, TenantQuota(...))` bounds a tenant's live
    directory bytes and entry count. Puts beyond the byte quota either
    reject (`QuotaExceededError`) or spill (the blob lands on disk via the
    node store's spill path instead of memory, so one tenant cannot evict
    everyone else's working set),
  * accounting: `tenant_usage(tenant)` reports live bytes/refs -- the
    fairness benchmark and the autoscaler read this.

The default path (everything under the implicit "default" tenant, no
guard, no quota) is behavior-identical to the single-tenant store.
"""
from __future__ import annotations

import os
import pickle
import threading
import time
import uuid
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Set

from repro.core.security import DEFAULT_TENANT, Capability, SecurityError


class QuotaExceededError(SecurityError):
    """A tenant tried to hold more than its admitted share of the store."""


@dataclass(frozen=True)
class TenantQuota:
    """Admission limits for one tenant's footprint in the store.

    `on_exceed="spill"` admits over-quota puts but forces the blob straight
    to the node's spill dir (memory relief at admission time; a later get()
    restores it through the normal LRU, which re-spills under node-capacity
    pressure). On a node without a spill dir the spill policy degrades to
    reject rather than silently keeping the blob in memory."""
    max_bytes: Optional[int] = None     # live directory bytes; None = unlimited
    max_refs: Optional[int] = None      # live directory entries
    on_exceed: str = "reject"           # "reject" | "spill" (bytes only)


@dataclass(frozen=True)
class ObjectRef:
    id: str
    size: int = 0
    producer_task: Optional[str] = None
    tenant: str = DEFAULT_TENANT

    @staticmethod
    def fresh(producer_task: Optional[str] = None, size: int = 0,
              tenant: str = DEFAULT_TENANT) -> "ObjectRef":
        return ObjectRef(id=uuid.uuid4().hex, size=size,
                         producer_task=producer_task, tenant=tenant)


class NodeStore:
    """Per-node object store with LRU spill to a scratch directory."""

    def __init__(self, node_id: str, capacity_bytes: int = 1 << 30,
                 spill_dir: Optional[str] = None):
        self.node_id = node_id
        self.capacity = capacity_bytes
        self.spill_dir = spill_dir
        self._mem: "OrderedDict[str, bytes]" = OrderedDict()
        self._spilled: Dict[str, str] = {}
        self._used = 0
        self._lock = threading.Lock()
        self.stats = {"puts": 0, "gets": 0, "spills": 0, "restores": 0}

    def put(self, ref: ObjectRef, value: Any) -> int:
        return self.put_blob(ref, pickle.dumps(
            value, protocol=pickle.HIGHEST_PROTOCOL))

    def put_blob(self, ref: ObjectRef, blob: bytes) -> int:
        """Store already-serialized bytes (replaces any prior copy)."""
        with self._lock:
            old = self._mem.pop(ref.id, None)
            if old is not None:            # re-put (e.g. reconstruction)
                self._used -= len(old)
            self._mem[ref.id] = blob
            self._mem.move_to_end(ref.id)
            self._used += len(blob)
            self.stats["puts"] += 1
            self._maybe_spill()
        return len(blob)

    def get(self, ref: ObjectRef) -> Any:
        with self._lock:
            self.stats["gets"] += 1
            if ref.id in self._mem:
                self._mem.move_to_end(ref.id)
                return pickle.loads(self._mem[ref.id])
            if ref.id in self._spilled:
                path = self._spilled[ref.id]
                with open(path, "rb") as f:
                    blob = f.read()
                self.stats["restores"] += 1
                self._mem[ref.id] = blob
                self._used += len(blob)
                self._maybe_spill()
                return pickle.loads(blob)
        raise KeyError(f"object {ref.id} not on node {self.node_id}")

    def has(self, ref: ObjectRef) -> bool:
        with self._lock:
            return ref.id in self._mem or ref.id in self._spilled

    def delete(self, ref: ObjectRef):
        with self._lock:
            blob = self._mem.pop(ref.id, None)
            if blob is not None:
                self._used -= len(blob)
            path = self._spilled.pop(ref.id, None)
            if path and os.path.exists(path):
                os.unlink(path)

    def export_blob(self, ref: ObjectRef) -> bytes:
        """Raw serialized bytes for migration (no pickle round-trip)."""
        with self._lock:
            if ref.id in self._mem:
                return self._mem[ref.id]
            if ref.id in self._spilled:
                with open(self._spilled[ref.id], "rb") as f:
                    return f.read()
        raise KeyError(f"object {ref.id} not on node {self.node_id}")

    def import_blob(self, ref: ObjectRef, blob: bytes):
        """Accept migrated bytes verbatim (counterpart of export_blob)."""
        with self._lock:
            if ref.id in self._mem or ref.id in self._spilled:
                return
            self._mem[ref.id] = blob
            self._used += len(blob)
            self.stats["puts"] += 1
            self._maybe_spill()

    def spill(self, ref: ObjectRef) -> bool:
        """Force one in-memory blob to disk now (tenant-quota spill path).
        Returns False when there is nothing to spill or no spill dir."""
        with self._lock:
            if self.spill_dir is None or ref.id not in self._mem:
                return False
            blob = self._mem.pop(ref.id)
            self._used -= len(blob)
            self._write_spill(ref.id, blob)
            return True

    def _maybe_spill(self):
        """LRU spill until under capacity (lock held)."""
        if self.spill_dir is None:
            return
        while self._used > self.capacity and self._mem:
            oid, blob = self._mem.popitem(last=False)
            self._used -= len(blob)
            self._write_spill(oid, blob)

    def _write_spill(self, oid: str, blob: bytes):
        os.makedirs(self.spill_dir, exist_ok=True)
        path = os.path.join(self.spill_dir, f"{self.node_id}_{oid}.obj")
        with open(path, "wb") as f:
            f.write(blob)
        self._spilled[oid] = path
        self.stats["spills"] += 1


@dataclass
class _Directory:
    locations: Set[str] = field(default_factory=set)
    refcount: int = 1
    producer_task: Optional[str] = None
    size: int = 0
    created: float = field(default_factory=time.monotonic)
    owner: Optional[str] = None       # node accountable for the primary copy
    tenant: str = DEFAULT_TENANT      # principal accountable for the bytes


class GlobalObjectStore:
    """Head-side directory over the per-node stores.

    Tracks locations, refcounts and lineage; transfers objects between node
    stores on demand (locality misses are recorded -- the benchmark's
    communication-cost model reads these counters).
    """

    def __init__(self):
        self._dir: Dict[str, _Directory] = {}
        self._nodes: Dict[str, NodeStore] = {}
        self._lock = threading.Lock()
        self._migration_guard = None   # optional (capability, token) pair
        self._token: Optional[str] = None            # set_access_guard
        self._quotas: Dict[str, TenantQuota] = {}
        self._usage: Dict[str, Dict[str, int]] = {}  # tenant -> bytes/refs
        self.stats = {"transfers": 0, "transfer_bytes": 0,
                      "reconstructions": 0,
                      "migrations": 0, "migrated_bytes": 0,
                      "quota_rejects": 0, "quota_spills": 0}

    # -- multi-tenancy: guard, quota, accounting -------------------------------

    def set_access_guard(self, token: str):
        """Install the cluster token so that get/put/migrate calls that
        present a Capability have it verified against the object's tenant.
        Calls without a capability stay trusted (head-internal plumbing);
        the threaded cluster passes per-task tenant capabilities, so every
        worker-side access is verified end to end."""
        self._token = token

    def set_quota(self, tenant: str, quota: TenantQuota):
        with self._lock:
            self._quotas[tenant] = quota

    def tenant_usage(self, tenant: str) -> Dict[str, int]:
        with self._lock:
            u = self._usage.get(tenant, {})
            return {"bytes": u.get("bytes", 0), "refs": u.get("refs", 0)}

    def tenant_of(self, ref_or_id) -> Optional[str]:
        oid = ref_or_id.id if isinstance(ref_or_id, ObjectRef) else ref_or_id
        with self._lock:
            e = self._dir.get(oid)
            return e.tenant if e else None

    def _check_capability(self, capability: Optional[Capability],
                          object_id: str, right: str, tenant: str):
        if capability is None:
            return
        if self._token is None:
            raise SecurityError(
                "capability presented but no access guard installed "
                "(head must set_access_guard with the cluster token)")
        capability.verify(self._token, object_id, right, tenant)

    def _usage_add(self, tenant: str, d_bytes: int, d_refs: int):
        """Adjust a tenant's live footprint (lock held)."""
        u = self._usage.setdefault(tenant, {"bytes": 0, "refs": 0})
        u["bytes"] += d_bytes
        u["refs"] += d_refs

    def _quota_verdict(self, tenant: str, add_bytes: int,
                       new_entry: bool) -> Optional[str]:
        """None = admitted; "spill" = admit but keep the blob on disk;
        raises QuotaExceededError on reject (lock held)."""
        q = self._quotas.get(tenant)
        if q is None:
            return None
        u = self._usage.get(tenant, {"bytes": 0, "refs": 0})
        if new_entry and q.max_refs is not None \
                and u["refs"] + 1 > q.max_refs:
            self.stats["quota_rejects"] += 1
            raise QuotaExceededError(
                f"tenant {tenant!r} over ref quota "
                f"({u['refs']}/{q.max_refs} live objects)")
        if q.max_bytes is not None and u["bytes"] + add_bytes > q.max_bytes:
            if q.on_exceed == "spill":
                self.stats["quota_spills"] += 1
                return "spill"
            self.stats["quota_rejects"] += 1
            raise QuotaExceededError(
                f"tenant {tenant!r} over byte quota "
                f"({u['bytes']} + {add_bytes} > {q.max_bytes})")
        return None

    def register_node(self, store: NodeStore):
        with self._lock:
            self._nodes[store.node_id] = store

    def unregister_node(self, node_id: str) -> Set[str]:
        """Remove a (failed) node; returns ids of objects that lost their
        last copy (candidates for lineage reconstruction)."""
        lost = set()
        with self._lock:
            self._nodes.pop(node_id, None)
            for oid, entry in self._dir.items():
                entry.locations.discard(node_id)
                if entry.owner == node_id:
                    # owner handoff to any surviving holder
                    entry.owner = next(iter(entry.locations), None)
                if not entry.locations:
                    lost.add(oid)
        return lost

    def has_node(self, node_id: str) -> bool:
        with self._lock:
            return node_id in self._nodes

    def put(self, node_id: str, value: Any,
            producer_task: Optional[str] = None,
            ref_id: Optional[str] = None,
            tenant: str = DEFAULT_TENANT,
            capability: Optional[Capability] = None) -> ObjectRef:
        """Store a new object under `tenant`. `ref_id` pins a deterministic
        object id (Ray-style): a reconstructed producer re-puts under the
        *same* id, so tasks waiting on the original ref wake up when it
        reappears. A presented capability is verified (right "put", tenant
        match); new objects are admitted against the tenant's quota --
        beyond it the put rejects (QuotaExceededError) or spills to disk,
        per the quota's `on_exceed` policy."""
        ref = (ObjectRef(ref_id, 0, producer_task, tenant) if ref_id
               else ObjectRef.fresh(producer_task, tenant=tenant))
        self._check_capability(capability, ref.id, "put", tenant)
        node = self._nodes[node_id]
        blob = pickle.dumps(value, protocol=pickle.HIGHEST_PROTOCOL)
        size = len(blob)
        spill = False
        # one atomic directory transaction decides admission (tenant check +
        # quota + registration) *before* any bytes land on the node store:
        # concurrent cross-tenant puts of the same id cannot both pass the
        # check and overwrite each other's blobs (the loser raises without
        # ever writing)
        with self._lock:
            e = self._dir.get(ref.id)
            if e is not None and e.tenant != tenant:
                raise SecurityError(
                    f"cross-tenant put denied: object {ref.id} belongs to "
                    f"tenant {e.tenant!r}, not {tenant!r}")
            if e is not None:              # reconstruction: revive the entry
                # already-admitted object: only the size delta is accounted
                # (no re-admission -- rolling back a revival would lose the
                # blob a waiting task is about to read)
                self._usage_add(e.tenant, size - e.size, 0)
                e.locations.add(node_id)
                e.size = size
                e.producer_task = producer_task or e.producer_task
                if e.owner is None:
                    e.owner = node_id
            else:
                spill = self._quota_verdict(tenant, size,
                                            new_entry=True) == "spill"
                self._usage_add(tenant, size, 1)
                self._dir[ref.id] = _Directory(locations={node_id},
                                               producer_task=producer_task,
                                               size=size, owner=node_id,
                                               tenant=tenant)
        node.put_blob(ref, blob)
        if spill and not node.spill(ref):
            # "spill" admission requires an actual spill dir on the node:
            # without one the blob would silently stay in memory, defeating
            # the quota -- unwind the registration and reject instead
            with self._lock:
                e2 = self._dir.get(ref.id)
                if e2 is not None and e2.locations == {node_id}:
                    self._usage_add(e2.tenant, -e2.size, -1)
                    del self._dir[ref.id]
                self.stats["quota_spills"] -= 1
                self.stats["quota_rejects"] += 1
            self._nodes[node_id].delete(ref)
            raise QuotaExceededError(
                f"tenant {tenant!r} over byte quota and node {node_id!r} "
                f"has no spill dir (on_exceed='spill' degraded to reject)")
        return ObjectRef(ref.id, size, producer_task, tenant)

    def get(self, node_id: str, ref: ObjectRef,
            capability: Optional[Capability] = None) -> Any:
        """Fetch on `node_id`, transferring from a remote copy if needed.
        A presented capability is verified against the object's tenant."""
        with self._lock:
            entry = self._dir.get(ref.id)
            local = node_id in (entry.locations if entry else ())
            src = next(iter(entry.locations)) if entry and entry.locations else None
            tenant = entry.tenant if entry else ref.tenant
        self._check_capability(capability, ref.id, "get", tenant)
        if local or (entry is None):
            return self._nodes[node_id].get(ref)
        if src is None:
            raise KeyError(f"object {ref.id} has no live copies")
        value = self._nodes[src].get(ref)
        self._nodes[node_id].put(ref, value)
        with self._lock:
            self._dir[ref.id].locations.add(node_id)
            self.stats["transfers"] += 1
            self.stats["transfer_bytes"] += self._dir[ref.id].size
        return value

    def locations(self, ref: ObjectRef) -> Set[str]:
        with self._lock:
            e = self._dir.get(ref.id)
            return set(e.locations) if e else set()

    def size_of(self, ref: ObjectRef) -> int:
        with self._lock:
            e = self._dir.get(ref.id)
            return e.size if e else ref.size

    def lineage(self, ref: ObjectRef) -> Optional[str]:
        with self._lock:
            e = self._dir.get(ref.id)
            return e.producer_task if e else ref.producer_task

    def add_ref(self, ref: ObjectRef, n: int = 1):
        with self._lock:
            if ref.id in self._dir:
                self._dir[ref.id].refcount += n

    def release(self, ref: ObjectRef):
        """Decrement refcount; free all copies at zero."""
        with self._lock:
            e = self._dir.get(ref.id)
            if e is None:
                return
            e.refcount -= 1
            if e.refcount > 0:
                return
            locs = set(e.locations)
            self._usage_add(e.tenant, -e.size, -1)
            del self._dir[ref.id]
        for node_id in locs:
            store = self._nodes.get(node_id)
            if store is not None:
                store.delete(ref)

    def note_reconstruction(self):
        with self._lock:
            self.stats["reconstructions"] += 1

    # -- drain / migration (see module docstring) -----------------------------

    def set_migration_guard(self, capability, token: str):
        """Require `capability` (right "migrate") for every migrate() call.
        Installed by the cluster head with a capability minted under the
        cluster token -- a tenant without it cannot move objects around."""
        self._migration_guard = (capability, token)

    def owner_of(self, ref: ObjectRef) -> Optional[str]:
        with self._lock:
            e = self._dir.get(ref.id)
            return e.owner if e else None

    def refcount(self, ref_or_id) -> int:
        oid = ref_or_id.id if isinstance(ref_or_id, ObjectRef) else ref_or_id
        with self._lock:
            e = self._dir.get(oid)
            return e.refcount if e else 0

    def objects_on(self, node_id: str) -> Dict[str, "ObjectRef"]:
        """Directory entries with a copy on `node_id`, keyed by object id.
        The migration planner filters these for sole-holder hot objects."""
        out: Dict[str, ObjectRef] = {}
        with self._lock:
            for oid, e in self._dir.items():
                if node_id in e.locations:
                    out[oid] = ObjectRef(oid, e.size, e.producer_task,
                                         e.tenant)
        return out

    def sole_holder(self, ref: ObjectRef, node_id: str) -> bool:
        with self._lock:
            e = self._dir.get(ref.id)
            return bool(e) and e.locations == {node_id}

    def migrate(self, ref: ObjectRef, src: str, dst: str,
                capability: Optional[Capability] = None) -> bool:
        """Move one object's copy src -> dst (raw blob, no pickle round-trip),
        updating the directory and handing off ownership if src owned it.
        Returns False when the move is moot (object gone, src copy gone, or
        dst unregistered) -- drains treat that as already-done.

        Tenant-aware guard: the presented capability (or the installed
        migration guard's) must cover the object's tenant. The head's guard
        is cluster-scoped (admin) and moves anything; a tenant-scoped
        capability raises SecurityError on another tenant's objects -- also
        when a drain tries to use it."""
        cap, token = capability, self._token
        if self._migration_guard is not None:
            guard_cap, guard_token = self._migration_guard
            cap = cap if cap is not None else guard_cap
            token = token if token is not None else guard_token
        if cap is not None:
            if token is None:
                raise SecurityError(
                    "capability presented but no access guard installed")
            cap.verify(token, "objects", "migrate",
                       self.tenant_of(ref.id) or ref.tenant)
        with self._lock:
            e = self._dir.get(ref.id)
            src_store = self._nodes.get(src)
            dst_store = self._nodes.get(dst)
            if e is None or src not in e.locations or dst_store is None:
                return False
            already_there = dst in e.locations
            if already_there:                # already replicated there
                e.locations.discard(src)
                if e.owner == src:
                    e.owner = dst
        if already_there:
            if src_store is not None:        # drop the now-unreachable blob
                src_store.delete(ref)
            return True
        if src_store is None:
            return False
        blob = src_store.export_blob(ref)
        dst_store.import_blob(ref, blob)
        with self._lock:
            e = self._dir.get(ref.id)
            if e is None:                    # released mid-copy
                dst_store.delete(ref)
                return False
            e.locations.add(dst)
            e.locations.discard(src)
            if e.owner == src:
                e.owner = dst                # owner handoff
            self.stats["migrations"] += 1
            self.stats["migrated_bytes"] += len(blob)
        src_store.delete(ref)
        return True
