"""Serving-plane benchmark: replica goodput scaling, weight broadcast,
and the live actor path.

The serving plane composes two batching layers -- slot-level continuous
batching inside each `ServeEngine` replica and token-level admission
across replicas in `serve/router.py` -- on top of the p2p data plane's
broadcast trees for weight distribution. This benchmark measures that
stack on the REAL Router/StubEngine/ObjectStore code:

1. *Goodput vs replica count*: an open-loop arrival stream at a fixed
   per-replica rate (so N replicas face N x the single-replica load)
   driven through `SimCluster.run_serve`. Reported per replica count:
   goodput (completed requests per virtual second), p99 end-to-end
   latency over the router's sliding window, and the head-link payload
   bytes (must stay 0 -- weights and results ride the worker NICs).
   The smoke gate: 4 replicas sustain >= 3x the single-replica goodput
   with BOTH arms inside the same p99 budget -- continuous batching
   across replicas must scale throughput without giving back the tail.

2. *Weight distribution*: a fat weights object broadcast to the replica
   fleet through the binomial tree (zero head payload bytes), then a
   scale-up replica placed on a bare worker -- its nearest-fresh fetch
   must come from a peer replica, never the head.

3. *Actor path* (real sockets): a worker-hosted `ReplicaActor` driven
   through actor_create/actor_call/actor_result/actor_exit with an
   `ActorReplicaHandle` + `Router` on top; routed outputs must match the
   engine run locally, and the router's `stats_sink` must surface the
   serving gauges (syndeo_serve_requests / shed / p99_ms and
   syndeo_replica_count) through the head's `metrics` op.

Run:  PYTHONPATH=src python benchmarks/serve_bench.py [--quick]
      PYTHONPATH=src python benchmarks/serve_bench.py --serve-smoke
"""
from __future__ import annotations

import argparse
import tempfile
import threading
import time
from typing import Dict, List

from repro.core import SimCluster, SimCostModel, SyndeoCluster
from repro.core.rendezvous import FileRendezvous
from repro.core.worker import HeadServer, _dec, _enc, _request, run_worker
from repro.serve.engine import Request, StubEngine
from repro.serve.router import ActorReplicaHandle, ReplicaActor, Router

MB = 1_000_000


# ------------------------------------------- goodput vs replica count


def serve_run(n_replicas: int, rate_rps: float, n_requests: int,
              tokens: int = 8, batch_slots: int = 4,
              weight_bytes: int = 8 * MB,
              tick_every: float = 0.01) -> Dict[str, float]:
    """One open-loop serving run: `n_requests` arrive evenly spaced at
    `rate_rps`, routed over `n_replicas` replica actors (one per sim
    worker, weights fetched p2p from the first worker's copy). Every
    request must complete with the engine-deterministic output."""
    cost = SimCostModel(task_time_s=lambda s: 0.05, jitter=0.0,
                        data_plane="p2p", result_location="worker")
    sim = SimCluster(cost)
    workers = sim.add_workers(n_replicas)
    weights = sim.store.put(workers[0], bytearray(weight_bytes))
    head0 = sim.store.stats["head_relayed_bytes"]
    router = Router(clock=lambda: sim.now)
    for i in range(n_replicas):
        handle = sim.add_replica(f"r{i}", batch_slots=batch_slots,
                                 weights=weights)
        assert handle is not None, f"replica r{i} did not place"
        router.add_replica(f"r{i}", handle)
    arrivals = [(i / rate_rps,
                 Request(id=i, prompt=[i, 17], max_new_tokens=tokens))
                for i in range(n_requests)]
    t0 = sim.now
    completed = sim.run_serve(router, arrivals, tick_every=tick_every)
    makespan = max(sim.now - t0, 1e-9)
    wrong = [q.id for q in completed
             if q.output != StubEngine.stub_output(q.prompt,
                                                   q.max_new_tokens)]
    assert not wrong, f"routed outputs diverged for requests {wrong}"
    return {"replicas": float(n_replicas),
            "rate_rps": rate_rps,
            "completed": float(len(completed)),
            "expected": float(n_requests),
            "goodput_rps": len(completed) / makespan,
            "p99_ms": router.p99_ms(),
            "makespan_s": makespan,
            "head_relayed_bytes": float(
                sim.store.stats["head_relayed_bytes"] - head0)}


def bench_serve(replica_counts: List[int], rate_per_replica: float = 40.0,
                requests_per_replica: int = 120) -> List[Dict[str, float]]:
    return [serve_run(n, rate_rps=rate_per_replica * n,
                      n_requests=requests_per_replica * n)
            for n in replica_counts]


def print_serve(rows: List[Dict[str, float]]):
    print("\n== serving plane: goodput + p99 vs replica count "
          "(per-replica load held constant) ==")
    print(f"{'replicas':>8} {'rate r/s':>9} {'goodput r/s':>12} "
          f"{'p99 ms':>8} {'scaling':>8} {'head MB':>8}")
    base = rows[0]["goodput_rps"] if rows else 1.0
    for r in rows:
        print(f"{r['replicas']:>8.0f} {r['rate_rps']:>9.0f} "
              f"{r['goodput_rps']:>12.1f} {r['p99_ms']:>8.1f} "
              f"{r['goodput_rps'] / max(base, 1e-9):>7.1f}x "
              f"{r['head_relayed_bytes'] / MB:>8.1f}")


# ------------------------------------------------- weight distribution


def weights_run(n_replicas: int = 4,
                obj_bytes: int = 8 * MB) -> Dict[str, float]:
    """Broadcast the weights object to the replica fleet through the
    binomial tree, then scale up one replica on a deliberately bare
    worker: its weights must arrive by a nearest-fresh peer fetch, with
    the head's NIC serving zero payload bytes throughout."""
    sim = SimCluster(SimCostModel(jitter=0.0, data_plane="p2p",
                                  result_location="worker"))
    ids = sim.add_workers(n_replicas + 2)
    weights = sim.store.put(ids[0], bytearray(obj_bytes))
    head0 = sim.store.stats["head_relayed_bytes"]
    makespan = sim.broadcast_object(weights, ids[1:n_replicas + 1],
                                    mode="tree")
    # fill every pre-warmed worker with a replica so the late joiner
    # can only land on the one bare worker (ids[-1]) and MUST fetch
    for i in range(n_replicas + 1):
        assert sim.add_replica(f"r{i}", weights=weights) is not None
    late = sim.add_replica("r-late", weights=weights)
    assert late is not None, "scale-up replica did not place"
    fetched = late.worker_id in sim.store.locations(weights)
    return {"consumers": float(n_replicas),
            "broadcast_s": makespan,
            "rounds": float(sim.store.stats["broadcast_rounds"]),
            "tree_edges": float(sim.store.stats["tree_edges"]),
            "head_relayed_bytes": float(
                sim.store.stats["head_relayed_bytes"] - head0),
            "scale_up_fetched": float(fetched),
            "scale_up_versioned": float(
                late.weights_version == weights.id)}


def print_weights(wr: Dict[str, float]):
    print("\n== weight distribution: broadcast tree + scale-up fetch ==")
    print(f"  consumers          : {wr['consumers']:.0f}")
    print(f"  broadcast makespan : {wr['broadcast_s']:.4f} s "
          f"({wr['rounds']:.0f} rounds, {wr['tree_edges']:.0f} edges)")
    print(f"  head payload bytes : {wr['head_relayed_bytes']:.0f}")
    print(f"  scale-up fetch     : "
          f"{'peer copy' if wr['scale_up_fetched'] else 'MISSING'}, "
          f"version "
          f"{'pinned' if wr['scale_up_versioned'] else 'UNPINNED'}")


# ------------------------------------------------- actor path (sockets)


def actor_run(n_requests: int = 3, tokens: int = 4) -> Dict[str, float]:
    """Real sockets: one worker-hosted ReplicaActor behind the router,
    with the router's stats_sink feeding the head's serve gauges."""
    with tempfile.TemporaryDirectory() as tmp:
        cluster = SyndeoCluster(rendezvous=FileRendezvous(tmp))
        server = HeadServer(cluster)
        server.attach()
        t = threading.Thread(
            target=run_worker, args=(tmp, cluster.cluster_id, "bench-w0"),
            kwargs={"max_idle_s": 1.0,
                    "actor_factories": {"replica": ReplicaActor}},
            daemon=True)
        t.start()
        try:
            deadline = time.time() + 20
            while time.time() < deadline and not any(
                    w.alive for w in cluster.scheduler.workers.values()):
                time.sleep(0.05)
            host, port, token = "127.0.0.1", server.port, cluster.token
            made = _request(host, port, token,
                            {"op": "actor_create", "factory": "replica",
                             "actor": "rep0",
                             "kwargs": {"batch_slots": 2}})
            assert made["ok"], made
            cap = made["cap"]

            def call(payload, timeout=10.0):
                sent = _request(host, port, token,
                                {"op": "actor_call", "actor": "rep0",
                                 "cap": cap, "payload": _enc(payload)})
                assert sent["ok"], sent
                limit = time.time() + timeout
                while time.time() < limit:
                    got = _request(host, port, token,
                                   {"op": "actor_result",
                                    "call": sent["call"]})
                    if got.get("done"):
                        assert not got.get("error"), got
                        return _dec(got["value"])
                    time.sleep(0.05)
                raise AssertionError("actor call never completed")

            router = Router(stats_sink=server.serve_stats.update)
            router.add_replica("rep0", ActorReplicaHandle(call))
            reqs = [Request(id=i, prompt=[i, 17], max_new_tokens=tokens)
                    for i in range(n_requests)]
            for q in reqs:
                assert router.submit(q)
            done = router.flush(max_ticks=200)
            outputs_ok = (
                sorted(q.id for q in done) == sorted(q.id for q in reqs)
                and all(q.output == StubEngine.stub_output(
                    q.prompt, q.max_new_tokens) for q in reqs))
            gauges = server.dispatch({"op": "metrics"})
            bye = _request(host, port, token,
                           {"op": "actor_exit", "actor": "rep0",
                            "cap": cap})
            assert bye["ok"], bye
            deadline = time.time() + 20
            while time.time() < deadline and (
                    "rep0" in cluster.scheduler.actors
                    or "bench-w0" in cluster.scheduler.workers):
                time.sleep(0.1)
            t.join(timeout=10)
        finally:
            server.shutdown()
            cluster.shutdown()
    return {"completed": float(len(done)),
            "outputs_ok": float(outputs_ok),
            "gauge_requests": float(gauges.get("syndeo_serve_requests", -1)),
            "gauge_shed": float(gauges.get("syndeo_serve_shed", -1)),
            "gauge_p99_ms": float(gauges.get("syndeo_serve_p99_ms", -1.0)),
            "gauge_replicas": float(gauges.get("syndeo_replica_count", -1))}


def print_actor(ar: Dict[str, float]):
    print("\n== actor path (real sockets): routed replica + serve gauges ==")
    print(f"  routed requests    : {ar['completed']:.0f} "
          f"({'outputs match engine' if ar['outputs_ok'] else 'DIVERGED'})")
    print(f"  gauges             : requests={ar['gauge_requests']:.0f} "
          f"shed={ar['gauge_shed']:.0f} p99={ar['gauge_p99_ms']:.1f}ms "
          f"replicas={ar['gauge_replicas']:.0f}")


# --------------------------------------------------------------- smoke


def serve_smoke() -> int:
    """CI gate: 4 replicas sustain >= 3x single-replica goodput at an
    equal p99 budget with every request completed; weight broadcast and
    scale-up fetch put ZERO payload bytes on the head's link; and the
    real-socket actor path routes correctly while exporting the serving
    gauges through the head's metrics op."""
    p99_budget_ms = 300.0
    one = serve_run(1, rate_rps=40.0, n_requests=120)
    four = serve_run(4, rate_rps=160.0, n_requests=480)
    print_serve([one, four])
    wr = weights_run()
    print_weights(wr)
    ar = actor_run()
    print_actor(ar)
    ok = True
    for r in (one, four):
        if r["completed"] != r["expected"]:
            print(f"FAIL: {r['replicas']:.0f}-replica run dropped "
                  f"{r['expected'] - r['completed']:.0f} requests")
            ok = False
        if r["p99_ms"] > p99_budget_ms:
            print(f"FAIL: {r['replicas']:.0f}-replica p99 "
                  f"{r['p99_ms']:.1f} ms over the {p99_budget_ms:.0f} ms "
                  f"budget")
            ok = False
        if r["head_relayed_bytes"] != 0:
            print(f"FAIL: serving run relayed "
                  f"{r['head_relayed_bytes']:.0f} payload bytes through "
                  f"the head")
            ok = False
    ratio = four["goodput_rps"] / max(one["goodput_rps"], 1e-9)
    if ratio < 3.0:
        print(f"FAIL: 4-replica goodput only {ratio:.2f}x single-replica "
              f"(need >= 3x at equal p99 budget)")
        ok = False
    if wr["head_relayed_bytes"] != 0:
        print(f"FAIL: weight broadcast put {wr['head_relayed_bytes']:.0f} "
              f"payload bytes on the head's link")
        ok = False
    if not (wr["scale_up_fetched"] and wr["scale_up_versioned"]):
        print("FAIL: scale-up replica missing its nearest-fresh weight "
              "copy or version pin")
        ok = False
    if not ar["outputs_ok"]:
        print("FAIL: socket-routed outputs diverged from the local engine")
        ok = False
    if ar["gauge_requests"] != ar["completed"] or ar["gauge_shed"] != 0:
        print(f"FAIL: serve gauges off (requests "
              f"{ar['gauge_requests']:.0f} != {ar['completed']:.0f} or "
              f"shed {ar['gauge_shed']:.0f} != 0)")
        ok = False
    if ar["gauge_replicas"] != 1 or ar["gauge_p99_ms"] <= 0:
        print(f"FAIL: replica_count {ar['gauge_replicas']:.0f} or p99 "
              f"gauge {ar['gauge_p99_ms']:.1f} not exported")
        ok = False
    print("\nserve smoke:", "PASS" if ok else "FAIL")
    return 0 if ok else 1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--serve-smoke", action="store_true")
    args = ap.parse_args()
    if args.serve_smoke:
        raise SystemExit(serve_smoke())
    counts = [1, 2, 4] if args.quick else [1, 2, 4, 8]
    print_serve(bench_serve(counts))
    print_weights(weights_run())
    print_actor(actor_run())


if __name__ == "__main__":
    main()
