"""Multi-device infrastructure tests. These need >1 XLA device, so each runs
in a subprocess with XLA_FLAGS set before jax import (the main pytest
process stays single-device, as the dry-run spec requires)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, devices: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env, cwd=REPO)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_small_mesh_dryrun_train_and_decode():
    """The dry-run machinery on a small (2,4) virtual mesh with the smoke
    config: lower + compile + roofline extraction end to end."""
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.configs.shapes import ShapeConfig
        from repro.models import build_model, input_specs
        from repro.optim.optimizers import make_optimizer, warmup_cosine
        from repro.train.steps import make_train_step, make_init_state
        from repro.sharding import axes as AX
        from repro.roofline import HloCostModel, roofline_terms

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = {"batch": ("data",), "model": ("model",), "expert": ("data",),
                 "ep_batch": (), "fsdp": (), "seq": ()}
        cfg = get_config("llama3-8b", smoke=True)
        model = build_model(cfg, n_groups=2)
        shape = ShapeConfig("t", "train", 32, 8)
        specs = input_specs(cfg, shape)
        opt = make_optimizer("adamw")
        step = make_train_step(model, opt, warmup_cosine(1e-3, 2, 10),
                               n_microbatches=2)
        with AX.axis_rules(mesh, rules):
            state_shapes = jax.eval_shape(make_init_state(model, opt),
                                          jax.random.PRNGKey(0))
            sds = jax.tree.map(lambda s: jax.ShapeDtypeStruct(
                s.shape, s.dtype), (state_shapes, specs))
            lowered = jax.jit(step).lower(*sds)
        compiled = lowered.compile()
        ma = compiled.memory_analysis()
        cm = HloCostModel(compiled.as_text())
        terms = roofline_terms(cm.entry_cost())
        assert terms["hlo_flops_per_device"] > 0
        assert ma.temp_size_in_bytes > 0
        print("OK", terms["hlo_flops_per_device"])
    """)
    assert "OK" in out


def test_roofline_trip_count_correction():
    """L layers scanned must cost ~L/2 x the 2-layer version (the raw
    cost_analysis would report them equal -- the parser must correct it)."""
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.roofline import HloCostModel

        def make(L):
            def layer(x, w):
                return jnp.tanh(x @ w), None
            def f(ws, x):
                y, _ = jax.lax.scan(layer, x, ws)
                return jnp.sum(y)
            c = jax.jit(f).lower(
                jax.ShapeDtypeStruct((L, 128, 128), jnp.float32),
                jax.ShapeDtypeStruct((64, 128), jnp.float32)).compile()
            return HloCostModel(c.as_text()).entry_cost().flops
        f2, f8 = make(2), make(8)
        ratio = f8 / f2
        assert 3.5 < ratio < 4.5, (f2, f8, ratio)
        print("OK", ratio)
    """, devices=1)
    assert "OK" in out


def test_compressed_allreduce_matches_psum():
    out = _run("""
        import jax, jax.numpy as jnp
        from functools import partial
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.optim.compression import compressed_psum_mean

        mesh = jax.make_mesh((8,), ("data",))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 128))
        e = jnp.zeros((8, 128))
        fn = shard_map(partial(compressed_psum_mean, axis_name="data"),
                       mesh=mesh, in_specs=(P("data"), P("data")),
                       out_specs=(P("data"), P("data")))
        mean, err = jax.jit(fn)(g, e)
        exact = jnp.broadcast_to(jnp.mean(g, 0, keepdims=True), g.shape)
        rel = float(jnp.max(jnp.abs(mean - exact)) / jnp.max(jnp.abs(exact)))
        assert rel < 0.05, rel
        # error feedback keeps the long-run average unbiased
        acc = jnp.zeros_like(g); err = jnp.zeros_like(g)
        for _ in range(20):
            m, err = jax.jit(fn)(g, err)
            acc = acc + m
        drift = float(jnp.max(jnp.abs(acc / 20 - exact)))
        assert drift < 0.02 * float(jnp.max(jnp.abs(exact))) + 0.02, drift
        print("OK", rel)
    """)
    assert "OK" in out


def test_checkpoint_restore_resharded():
    """Save on one topology, restore under different shardings (elastic
    restart after losing nodes)."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint.checkpointer import Checkpointer

        mesh8 = jax.make_mesh((8,), ("d",))
        sh8 = NamedSharding(mesh8, P("d"))
        state = {"w": jax.device_put(jnp.arange(64.0).reshape(8, 8), sh8)}
        d = tempfile.mkdtemp()
        ck = Checkpointer(d)
        ck.save(1, state, blocking=True)

        mesh4 = jax.make_mesh((4, 2), ("d", "m"))
        sh_new = {"w": NamedSharding(mesh4, P("m", "d"))}
        out = ck.restore(jax.eval_shape(lambda: state), shardings=sh_new)
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.arange(64.0).reshape(8, 8))
        assert out["w"].sharding == sh_new["w"]
        print("OK")
    """)
    assert "OK" in out


def test_tcp_worker_protocol():
    """Real head-worker protocol over TCP sockets (paper phases 2-4) with a
    worker subprocess joining via the file rendezvous."""
    out = _run("""
        import subprocess, sys, os, tempfile, threading, time
        from repro.core.cluster import SyndeoCluster
        from repro.core.rendezvous import FileRendezvous
        from repro.core.worker import HeadServer

        rdv_dir = tempfile.mkdtemp()
        cluster = SyndeoCluster(rendezvous=FileRendezvous(rdv_dir))
        server = HeadServer(cluster)
        server.attach()

        env = dict(os.environ)
        env["PYTHONPATH"] = "src"
        worker = subprocess.Popen(
            [sys.executable, "-m", "repro.core.worker", "--role", "worker",
             "--rendezvous", rdv_dir, "--cluster-id", cluster.cluster_id,
             "--max-idle-s", "15"], env=env)
        try:
            deadline = time.time() + 20
            while time.time() < deadline and not any(
                    w.startswith("tcp-") for w in cluster.scheduler.workers):
                time.sleep(0.2)
            assert any(w.startswith("tcp-") for w in cluster.scheduler.workers)
            t = cluster.submit(pow, 2, 10)
            assert cluster.get(t, timeout=30) == 1024
        finally:
            worker.terminate()
            server.shutdown()
            cluster.shutdown()
        print("OK")
    """, devices=1, timeout=180)
    assert "OK" in out
