"""Security layer: the Apptainer principle, applied to the runtime.

The paper's security argument: containers run as *normal processes under
the user's account* -- no root daemon, administrators keep control. The
runtime equivalents implemented here:

  * UnprivilegedProfile -- refuses to run the cluster as root (mirroring
    Apptainer's no-root-daemon design), enforces a restrictive umask and
    an allowlisted scratch directory.
  * Cluster token + HMAC-signed message envelopes -- every head<->worker
    RPC is authenticated with a token minted at rendezvous; a node that
    does not hold the token cannot join or inject work (multi-tenant
    safety on a shared fabric).
  * Capability tokens -- object-store access grants scoped to an object id
    and a right ("get"/"put"), signed with the cluster key.
"""
from __future__ import annotations

import hashlib
import hmac
import json
import os
import secrets
import time
from dataclasses import dataclass
from typing import Any, Dict, Optional


class SecurityError(RuntimeError):
    pass


def mint_cluster_token() -> str:
    return secrets.token_hex(32)


@dataclass(frozen=True)
class UnprivilegedProfile:
    """Execution profile every worker asserts before starting."""
    allow_root: bool = False
    umask: int = 0o077
    scratch_root: str = "/tmp"

    def enforce(self):
        if not self.allow_root and hasattr(os, "geteuid") and os.geteuid() == 0:
            # Multi-tenant HPC refuses root workers (Apptainer design). The
            # container CI runs as root, so tests construct the profile with
            # allow_root=True -- exactly the "single-tenant" relaxation the
            # paper describes for personal cloud instances.
            raise SecurityError(
                "refusing to start a worker as root: Syndeo workers run as "
                "normal user processes (see DESIGN.md / Apptainer security "
                "model); pass allow_root=True only on single-tenant nodes")
        os.umask(self.umask)

    def scratch_dir(self, cluster_id: str) -> str:
        path = os.path.join(self.scratch_root, f"syndeo-{cluster_id}")
        os.makedirs(path, mode=0o700, exist_ok=True)
        return path


def sign(token: str, payload: bytes) -> str:
    return hmac.new(token.encode(), payload, hashlib.sha256).hexdigest()


def seal(token: str, msg: Dict[str, Any]) -> Dict[str, Any]:
    """Wrap a message in a signed envelope."""
    body = json.dumps(msg, sort_keys=True, default=repr).encode()
    return {"body": msg, "ts": time.time(),
            "mac": sign(token, body)}


def open_sealed(token: str, envelope: Dict[str, Any],
                max_age_s: float = 3600.0) -> Dict[str, Any]:
    body = json.dumps(envelope.get("body", {}), sort_keys=True,
                      default=repr).encode()
    mac = envelope.get("mac", "")
    if not hmac.compare_digest(mac, sign(token, body)):
        raise SecurityError("HMAC verification failed: message rejected")
    if time.time() - envelope.get("ts", 0) > max_age_s:
        raise SecurityError("stale message rejected (replay window)")
    return envelope["body"]


@dataclass(frozen=True)
class Capability:
    object_id: str
    right: str          # "get" | "put"
    mac: str

    @staticmethod
    def grant(token: str, object_id: str, right: str) -> "Capability":
        mac = sign(token, f"{object_id}:{right}".encode())
        return Capability(object_id, right, mac)

    def check(self, token: str, object_id: str, right: str):
        want = sign(token, f"{object_id}:{right}".encode())
        if (self.object_id != object_id or self.right != right
                or not hmac.compare_digest(self.mac, want)):
            raise SecurityError(
                f"capability check failed for {right}:{object_id}")
