"""syndeo-lint pass 1: lock discipline.

SYN-L001  blocking call (socket op, Transport.fetch/push, sleep/wait,
          subprocess) reachable while a ``with self._lock`` region is
          held.  Direct leaves and transitive call chains both count;
          transitive findings carry a witness chain in the message.

SYN-L002  lock-acquisition-order cycle: an edge A -> B is recorded when
          lock B is acquired (directly, or anywhere in a callee) while
          A is held.  Any cycle in that graph is a potential deadlock.
"""
from __future__ import annotations

from typing import Dict, List, Set, Tuple

from repro.analysis.model import CodeModel, Finding


def check_locks(model: CodeModel) -> List[Finding]:
    findings = _blocking_under_lock(model)
    findings.extend(_lock_order_cycles(model))
    return findings


def _blocking_under_lock(model: CodeModel) -> List[Finding]:
    findings: List[Finding] = []
    blocking = model.blocking_info()
    seen: Set[Tuple[str, int]] = set()
    for fn in model.functions.values():
        for cs in fn.calls:
            if not cs.under_locks:
                continue
            dedupe = (fn.file, cs.line)
            if dedupe in seen:
                continue
            if cs.blocking:
                seen.add(dedupe)
                findings.append(Finding(
                    "SYN-L001", fn.file, cs.line, fn.qualname,
                    f"blocking call {cs.display}() while holding "
                    f"{cs.under_locks[-1]}"))
                continue
            for tgt in model.resolve_call(fn, cs):
                if tgt.key in blocking:
                    seen.add(dedupe)
                    chain = model.blocking_chain(tgt.key)
                    findings.append(Finding(
                        "SYN-L001", fn.file, cs.line, fn.qualname,
                        f"call {cs.display}() can block while holding "
                        f"{cs.under_locks[-1]} (via {chain})"))
                    break
    return findings


def _lock_order_cycles(model: CodeModel) -> List[Finding]:
    # edge (held -> acquired) -> first witness (file, line, function)
    edges: Dict[Tuple[str, str], Tuple[str, int, str]] = {}
    acquired = model.acquired_info()
    for fn in model.functions.values():
        for acq in fn.lock_acqs:
            for held in acq.held:
                if held != acq.lock_id:
                    edges.setdefault((held, acq.lock_id),
                                     (fn.file, acq.line, fn.qualname))
        for cs in fn.calls:
            if not cs.under_locks:
                continue
            for tgt in model.resolve_call(fn, cs):
                for lid in acquired.get(tgt.key, {}):
                    for held in cs.under_locks:
                        if held != lid:
                            edges.setdefault(
                                (held, lid),
                                (fn.file, cs.line, fn.qualname))

    adj: Dict[str, List[str]] = {}
    for a, b in edges:
        adj.setdefault(a, []).append(b)

    findings: List[Finding] = []
    reported: Set[Tuple[str, ...]] = set()
    for a, b in sorted(edges):
        path = _find_path(adj, b, a)
        if path is None:
            continue
        cycle = [a] + path  # a -> b -> ... -> a
        canon = _canonical_cycle(cycle)
        if canon in reported:
            continue
        reported.add(canon)
        file, line, func = edges[(a, b)]
        pretty = " -> ".join(cycle + [cycle[0]]
                             if cycle[-1] != cycle[0] else cycle)
        findings.append(Finding(
            "SYN-L002", file, line, func,
            f"lock-order cycle: {pretty} "
            f"(edge {a} -> {b} witnessed here)"))
    return findings


def _find_path(adj: Dict[str, List[str]], start: str,
               goal: str) -> "List[str] | None":
    """DFS path start..goal (inclusive), or None."""
    stack: List[Tuple[str, List[str]]] = [(start, [start])]
    seen: Set[str] = set()
    while stack:
        node, path = stack.pop()
        if node == goal:
            return path
        if node in seen:
            continue
        seen.add(node)
        for nxt in adj.get(node, ()):
            stack.append((nxt, path + [nxt]))
    return None


def _canonical_cycle(nodes: List[str]) -> Tuple[str, ...]:
    ring = nodes[:-1] if len(nodes) > 1 and nodes[-1] == nodes[0] \
        else nodes
    if not ring:
        return ()
    pivot = min(range(len(ring)), key=lambda i: ring[i])
    return tuple(ring[pivot:] + ring[:pivot])
