"""Pallas TPU flash attention (training/prefill): causal, GQA, windowed.

TPU-native design (not a CUDA port):
  * grid = (batch, q_heads, num_q_blocks, num_k_blocks); the k dimension is
    the minor-most ("arbitrary" semantics) so the online-softmax state lives
    in VMEM scratch across k steps -- no HBM round-trips for acc/m/l,
  * BlockSpec tiles are MXU-aligned (block_q x head_dim, head_dim a
    multiple of 128 -- ops.py pads when needed),
  * GQA is folded into the k/v index_map (q-head h reads kv-head h // R) --
    KV is never materialized repeated,
  * causal masking by block; fully-masked k blocks issue no MXU work
    (pl.when guard).

Layout: q (B, Hq, Tq, D); k/v (B, Hkv, Tk, D); out like q. fp32 softmax.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, block_q: int, block_k: int, n_kb: int,
                  causal: bool, window: Optional[int]):
    iq = pl.program_id(2)
    jk = pl.program_id(3)

    @pl.when(jk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * block_q
    k_start = jk * block_k

    # skip k blocks that are entirely in the causal future / outside window
    needed = jnp.asarray(True)
    if causal:
        needed = needed & (k_start <= q_start + block_q - 1)
    if window is not None:
        needed = needed & (k_start + block_k - 1 > q_start - window)

    @pl.when(needed)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None]) * mask       # masked rows stay 0
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_prev * alpha[:, None] + pv
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(jk == n_kb - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 256, block_k: int = 256,
                    interpret: bool = True) -> jax.Array:
    """q: (B, Hq, Tq, D); k/v: (B, Hkv, Tk, D) -> out (B, Hq, Tq, D)."""
    B, Hq, Tq, D = q.shape
    Hkv, Tk = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0, (Hq, Hkv)
    R = Hq // Hkv
    block_q = min(block_q, Tq)
    block_k = min(block_k, Tk)
    assert Tq % block_q == 0 and Tk % block_k == 0
    n_qb, n_kb = Tq // block_q, Tk // block_k
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(_flash_kernel, scale=scale, block_q=block_q,
                               block_k=block_k, n_kb=n_kb, causal=causal,
                               window=window)
    return pl.pallas_call(
        kernel,
        grid=(B, Hq, n_qb, n_kb),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j: (b, h // R, j, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j: (b, h // R, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
