"""Virtual-time discrete-event backend for the Syndeo scheduler.

Runs the *real* Scheduler / GlobalObjectStore code with a simulated clock
and a parametric cost model, so paper-scale clusters (868 CPU workers) can
be benchmarked faithfully on this 1-core container. The cost model captures
exactly the effects the paper measures:

  * per-task dispatch overhead at the head (serialized -- the head is one
    process),
  * result-artifact transfer through the head's link (serialized queue;
    Humanoid's 376-float observations x 1000 steps are ~3 MB/task, which is
    what collapses its scaling in Table II),
  * per-worker compute time with optional jitter / slowdown injection
    (stragglers), and worker failure injection.
"""
from __future__ import annotations

import heapq
import random
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.autoscaler import Autoscaler, AutoscalerConfig
from repro.core.metrics import (build_cluster_metrics, render_dashboards,
                                render_prometheus)
from repro.core.object_store import (GlobalObjectStore, NodeStore, ObjectRef,
                                     TenantQuota)
from repro.core.scheduler import Scheduler, SchedulerConfig, WorkerInfo
from repro.core.security import SecurityError
from repro.core.task_graph import Task, TaskSpec, TaskState


@dataclass
class SimCostModel:
    task_time_s: Callable[[TaskSpec], float] = lambda spec: 1.0
    result_bytes: Callable[[TaskSpec], float] = lambda spec: 1024.0
    dispatch_overhead_s: float = 0.002        # head-side serial dispatch
    head_bandwidth_Bps: float = 1.0e9         # 10GbE-ish effective
    jitter: float = 0.05                      # lognormal-ish runtime noise
    # drain-pipeline costs: worker-to-worker object migration runs over the
    # node NICs, not the serialized head link
    migration_bandwidth_Bps: float = 1.0e9
    migration_overhead_s: float = 0.001       # per-object control message
    # where task results materialize: "head" (seed behavior: artifacts land
    # on the head store) or "worker" (Ray-faithful: the producer's node
    # store owns the primary copy -- what drains must migrate)
    result_location: str = "head"
    # per-link data-plane model. None = legacy (dependency transfers are
    # not modeled -- the seed behavior every older benchmark ran under).
    # "relay": every dep fetch serializes on the head's one NIC (the
    # conflated control/data plane the paper's Table II suffers from);
    # "p2p": deps move producer-worker -> consumer-worker, each node's NIC
    # serializing independently, so aggregate bandwidth scales with the
    # worker count. Pair "relay" with result_location="head" and "p2p"
    # with result_location="worker" for a coherent comparison.
    data_plane: Optional[str] = None
    node_bandwidth_Bps: float = 1.0e9         # per-worker NIC
    link_latency_s: float = 0.0005            # per-transfer setup cost


def lognormal_provision_latency(median_s: float = 120.0, sigma: float = 1.0,
                                floor_s: float = 5.0
                                ) -> Callable[[random.Random], float]:
    """Heavy-tailed provisioning latency sampler for the outer resource
    manager, shaped like GCP TPU queued-resource creation: lognormal with
    the given median, so sigma=1.0 puts p95 near 5x the median and the
    occasional slice arrives an order of magnitude late. Feed it to
    `SimCluster.set_provision_latency` to sanity-check
    `AutoscalerConfig.for_backend("gcp_tpu")` cooldowns against realistic
    allocation tails."""
    import math
    mu = math.log(max(median_s, 1e-9))

    def sample(rng: random.Random) -> float:
        return max(floor_s, rng.lognormvariate(mu, sigma))
    return sample


class SimReplicaHandle:
    """A serving replica living on a simulated worker: the router's
    engine duck-type over a local `StubEngine`, plus the placement
    metadata the chaos tests assert on (hosting worker, weight version).
    Decode latency falls out of the driver's tick cadence -- each
    `run_serve` tick is one decode step per slot -- so queueing delay is
    what moves the router's p99."""

    def __init__(self, replica_id: str, worker_id: str, engine,
                 weights_version: Optional[str] = None):
        self.id = replica_id
        self.worker_id = worker_id
        self.engine = engine
        self.weights_version = weights_version

    @property
    def free_slots(self) -> int:
        return self.engine.free_slots

    @property
    def queue_len(self) -> int:
        return self.engine.queue_len

    @property
    def outstanding_tokens(self) -> int:
        return self.engine.outstanding_tokens

    def add_request(self, req):
        self.engine.add_request(req)

    def tick(self) -> int:
        return self.engine.tick()

    def pop_completed(self):
        return self.engine.pop_completed()

    def run_until_drained(self, max_ticks: int = 10000):
        return self.engine.run_until_drained(max_ticks=max_ticks)


class SimCluster:
    """Discrete-event cluster. API mirrors SyndeoCluster where relevant."""

    def __init__(self, cost: SimCostModel,
                 scheduler_config: SchedulerConfig = SchedulerConfig(),
                 seed: int = 0):
        self.cost = cost
        self.now = 0.0
        self._seq = 0
        self._events: List[Tuple[float, int, Callable[[], None]]] = []
        self.rng = random.Random(seed)
        # one knob sizes both halves of the control plane (shards=1 == seed)
        self.store = GlobalObjectStore(shards=scheduler_config.shards)
        self.scheduler = Scheduler(self.store, self._launch, lambda t, w: None,
                                   scheduler_config, clock=lambda: self.now)
        # drains execute migrations with modeled transfer latency
        self.scheduler.migrate_fn = self._migrate_object
        self._head_store = NodeStore("head", capacity_bytes=1 << 30)
        self.store.register_node(self._head_store)
        self._head_link_free = 0.0   # serialized head NIC
        self._head_dispatch_free = 0.0
        self._nic_free: Dict[str, float] = {}   # per-worker NIC serialization
        # (src, dst) -> virtual instant of the last full-priced move:
        # same-destination moves dispatched at one instant coalesce into
        # one multi-blob frame, so only the first pays connect + ticket
        self._batch_slot: Dict[Tuple[str, str], float] = {}
        self._worker_speed: Dict[str, float] = {}
        self._next_worker = 0        # monotonic: retired ids never reused
        self._dead: set = set()
        self.autoscaler: Optional[Autoscaler] = None
        self.replicas: Dict[str, "SimReplicaHandle"] = {}
        self.completed: List[Task] = []
        # heavy-tailed outer-RM provisioning latency (e.g. GCP TPU queued
        # resources): when set, each provisioned worker joins after its own
        # sampled delay instead of the fixed provision_workers delay_s
        self.provision_latency_fn: Optional[
            Callable[[random.Random], float]] = None

    # -- event loop -------------------------------------------------------------

    def _post(self, delay: float, fn: Callable[[], None]):
        self._seq += 1
        heapq.heappush(self._events, (self.now + delay, self._seq, fn))

    def run(self, until: Optional[float] = None):
        while self._events:
            t, _, fn = heapq.heappop(self._events)
            if until is not None and t > until:
                self.now = until
                return
            self.now = max(self.now, t)
            fn()

    # -- membership ----------------------------------------------------------------

    def add_workers(self, n: int, cpus_per_worker: float = 1.0,
                    speed: float = 1.0, prefix: str = "w",
                    capacity_bytes: int = 1 << 30) -> List[str]:
        ids = []
        for i in range(n):
            wid = f"{prefix}{self._next_worker}"
            self._next_worker += 1
            self.store.register_node(NodeStore(wid,
                                               capacity_bytes=capacity_bytes))
            self._worker_speed[wid] = speed
            self.scheduler.add_worker(WorkerInfo(wid, {"cpu": cpus_per_worker}))
            ids.append(wid)
        return ids

    def set_worker_speed(self, worker_id: str, speed: float):
        self._worker_speed[worker_id] = speed

    # -- elasticity (driven by the autoscaler / SimBackend) ----------------------

    def provision_workers(self, n: int, cpus_per_worker: float = 1.0,
                          delay_s: float = 1.0):
        """Provision `n` workers that join after `delay_s` of virtual time
        (the outer resource manager's allocation latency). When a
        provisioning-latency distribution is installed
        (`set_provision_latency`), each worker instead joins after its own
        sampled delay -- queued-resource slices land one by one, sometimes
        minutes apart, which is what the gcp_tpu cooldown defaults are
        tuned against."""
        def join_one():
            for wid in self.add_workers(1, cpus_per_worker=cpus_per_worker):
                if self.autoscaler is not None:
                    self.autoscaler.note_joined(wid)

        if self.provision_latency_fn is not None:
            for _ in range(n):
                self._post(max(0.0, float(self.provision_latency_fn(self.rng))),
                           join_one)
            return

        def join():
            for wid in self.add_workers(n, cpus_per_worker=cpus_per_worker):
                if self.autoscaler is not None:
                    self.autoscaler.note_joined(wid)
        self._post(delay_s, join)

    def set_provision_latency(self, fn: Callable[[random.Random], float]):
        """Install a per-worker provisioning latency sampler (see
        `lognormal_provision_latency`)."""
        self.provision_latency_fn = fn

    def release_workers(self, worker_ids: List[str]):
        for wid in worker_ids:
            self._worker_speed.pop(wid, None)

    def attach_autoscaler(self, config: Optional[AutoscalerConfig] = None,
                          provision_delay_s: float = 1.0) -> Autoscaler:
        cfg = config or AutoscalerConfig()

        def provision(count: int, resources: Dict[str, float]) -> int:
            self.provision_workers(count,
                                   cpus_per_worker=resources.get("cpu", 1.0),
                                   delay_s=provision_delay_s)
            return count

        self.autoscaler = Autoscaler(self.scheduler, provision,
                                     self.release_workers, cfg,
                                     clock=lambda: self.now)
        return self.autoscaler

    def register_tenant(self, tenant_id: str, weight: float = 1.0,
                        quota_bytes: Optional[int] = None,
                        quota_refs: Optional[int] = None,
                        on_exceed: str = "reject",
                        quota_bytes_per_node: Optional[int] = None):
        """Tenant admission (SyndeoCluster.register_tenant's sim twin):
        fair-share weight on the scheduler, optional store quota."""
        self.scheduler.register_tenant(tenant_id, weight)
        if (quota_bytes is not None or quota_refs is not None
                or quota_bytes_per_node is not None):
            self.store.set_quota(tenant_id, TenantQuota(
                max_bytes=quota_bytes, max_refs=quota_refs,
                on_exceed=on_exceed,
                max_bytes_per_node=quota_bytes_per_node))

    def fail_worker_at(self, worker_id: str, t: float):
        def fail():
            self._dead.add(worker_id)
            self.scheduler.on_worker_failed(worker_id, reason="injected")
        self._post(max(0.0, t - self.now), fail)

    # -- drain pipeline (graceful retirement with object migration) ------------

    def _migrate_object(self, worker_id: str, ref, dst: str):
        """Scheduler migrate hook: one two-phase object move. PREPARE at
        dispatch (directory in-flight state, guard-checked), then the
        modeled transfer, then the copy lands and COMMITs.

        Link model mirrors _fetch_deps: under `data_plane="p2p"` the move
        is a *direct* worker->survivor push serializing only the two
        endpoints' NICs (the head's link carries zero migration bytes --
        what the drain-p2p benchmark asserts); under `"relay"` every move
        is two hops on the head's serialized NIC and is counted in
        head_relayed_bytes; None keeps the legacy flat-latency model."""
        try:
            prepared = self.store.begin_move(ref, worker_id, dst)
        except SecurityError:
            # tenant-scoped guard: this object is not ours to move --
            # degrade to drop + lineage for it
            self.scheduler.note_migration_denied(worker_id, ref)
            return
        if not prepared:
            # object gone / already mid-move: re-plan on the next scan
            self.scheduler.note_migration_failed(worker_id, ref)
            return
        if self.cost.data_plane == "p2p":
            # batched move frames: moves to the same destination
            # dispatched at the same virtual instant ride one connection
            # -- only the first pays the per-connection overhead, the
            # rest pay bytes only (mirrors run_worker's push_batch path)
            key = (worker_id, dst)
            first_in_frame = self._batch_slot.get(key) != self.now
            self._batch_slot[key] = self.now
            overhead = (self.cost.migration_overhead_s
                        + self.cost.link_latency_s)
            if not first_in_frame:
                overhead = 0.0
                self.store.stats["batched_moves"] += 1
            dt = overhead + ref.size / self.cost.migration_bandwidth_Bps
            t_src = max(self._nic_free.get(worker_id, 0.0), self.now) + dt
            t_dst = max(self._nic_free.get(dst, 0.0), self.now) + dt
            self._nic_free[worker_id] = t_src
            self._nic_free[dst] = t_dst
            delay = max(t_src, t_dst) - self.now
        elif self.cost.data_plane == "relay":
            dt = 2 * (self.cost.link_latency_s
                      + ref.size / self.cost.head_bandwidth_Bps)
            t1 = max(self._head_link_free, self.now) + dt
            self._head_link_free = t1
            delay = t1 - self.now
        else:
            delay = (self.cost.migration_overhead_s
                     + ref.size / self.cost.migration_bandwidth_Bps)

        def land():
            if self.store.complete_move(ref, worker_id, dst):
                if self.cost.data_plane == "relay":
                    # attempt-idempotent accounting: bill the head NIC
                    # only for a move that actually landed -- a re-planned
                    # failed move used to charge its bytes once per try
                    self.store.stats["head_relayed_bytes"] += ref.size
                self.scheduler.note_migrated(worker_id, ref)
            else:
                # destination died or object already settled: re-plan
                self.scheduler.note_migration_failed(worker_id, ref)
        self._post(delay, land)

    def broadcast_object(self, ref, consumers: List[str],
                         mode: str = "tree") -> float:
        """Model a fat-object broadcast to `consumers`; returns the
        makespan in virtual seconds. "npush" is the baseline: the
        producer pushes every copy itself, so its single NIC serializes
        N per-link transfers. "tree" executes the store's binomial
        broadcast (real directory + byte movement, per-edge stats) and
        charges one parallel per-link cost per round, so makespan grows
        ~log2(N). Neither mode touches the head link -- the broadcast
        smoke gate asserts head_relayed_bytes stays 0."""
        dt = (self.cost.link_latency_s
              + ref.size / self.cost.node_bandwidth_Bps)
        if mode == "npush":
            src = self.store.choose_source(ref, "")
            makespan = 0.0
            for dst in sorted(set(consumers)):
                if self.store.fetch(dst, ref, src=src):
                    makespan += dt       # source NIC serializes each push
            return makespan
        if mode != "tree":
            raise ValueError(f"unknown broadcast mode {mode!r}")
        rounds0 = self.store.stats["broadcast_rounds"]
        self.store.broadcast(ref, consumers)
        rounds = self.store.stats["broadcast_rounds"] - rounds0
        return rounds * dt

    def drain_worker_at(self, worker_id: str, t: float,
                        deadline_s: Optional[float] = None,
                        poll_every: float = 0.05):
        """Eviction notice at virtual time `t`: the worker enters DRAINING
        (no new placements), running tasks finish -- or are preempted
        `deadline_s` after the notice -- hot objects migrate to survivors,
        and the node is then released. The graceful twin of fail_worker_at."""
        def poll():
            if worker_id not in self.scheduler.workers:
                return                        # failed or already released
            self.scheduler.check_drains(self.now)
            if self.scheduler.drain_complete(worker_id) \
                    and self.scheduler.finish_drain(worker_id):
                self.release_workers([worker_id])
                return
            self._post(poll_every, poll)

        def start():
            if self.scheduler.begin_drain(worker_id, deadline_s):
                poll()
        self._post(max(0.0, t - self.now), start)

    # -- serving plane (long-running replica actors) -----------------------------

    def add_replica(self, replica_id: str, batch_slots: int = 4,
                    resources: Optional[Dict[str, float]] = None,
                    weights=None, tenant_id: str = "default",
                    placement_group: Optional[str] = None,
                    bundle_index: Optional[int] = None
                    ) -> Optional["SimReplicaHandle"]:
        """Place a serving replica as a long-running actor: lifetime
        resource hold via `place_actor`, then a nearest-fresh weight fetch
        -- `choose_source` prefers worker peers holding a fresh copy over
        the head, so scale-up weight distribution stays off the head link
        (head_relayed_bytes unchanged). Returns None when nothing fits."""
        from repro.serve.engine import StubEngine
        wid = self.scheduler.place_actor(
            replica_id, resources or {"cpu": 1.0}, tenant_id=tenant_id,
            placement_group=placement_group, bundle_index=bundle_index)
        if wid is None:
            return None
        version = None
        if weights is not None:
            if wid not in self.store.locations(weights):
                src = self.store.choose_source(weights, wid)
                self.store.fetch(wid, weights, src=src)
            version = weights.id
        handle = SimReplicaHandle(replica_id, wid, StubEngine(batch_slots),
                                  weights_version=version)
        self.replicas[replica_id] = handle
        return handle

    def remove_replica(self, replica_id: str) -> bool:
        """Graceful replica exit: release the actor's lifetime resource
        hold. The caller is responsible for draining the replica's
        in-flight decodes first (`Router.retire_replica`)."""
        self.replicas.pop(replica_id, None)
        return self.scheduler.remove_actor(replica_id)

    def handoff_replicas(self, worker_id: str, router, weights=None
                         ) -> List[str]:
        """Move every replica hosted on `worker_id` to survivors: each is
        retired from the router (finishes its in-flight decodes -- no
        request is dropped), its actor registration released, and a
        successor placed elsewhere with a nearest-fresh weight fetch. Run
        after `begin_drain` so successors cannot land back on the
        draining host. Returns the successor replica ids."""
        moved: List[str] = []
        for rid in self.scheduler.actors_on(worker_id):
            old = self.replicas.get(rid)
            slots = old.engine.B if old is not None else 4
            router.retire_replica(rid)
            self.remove_replica(rid)
            new_id = f"{rid}+"
            nh = self.add_replica(new_id, batch_slots=slots, weights=weights)
            if nh is not None:
                router.add_replica(new_id, nh)
                moved.append(new_id)
        return moved

    def preempt_worker_at(self, worker_id: str, t: float, notice_s: float,
                          router=None, weights=None,
                          poll_every: float = 0.05):
        """Preemption notice at virtual time `t` (spot reclaim, queued
        resource revocation): the node WILL be revoked `notice_s` later
        regardless. Inside the notice window the drain plane does its
        graceful work -- replicas hand off through the router, hot
        objects migrate to survivors -- and a node that drains in time is
        released cleanly (zero re-execution). Only a node still holding
        work at the deadline is hard-killed through the failure path."""
        def start():
            if worker_id not in self.scheduler.workers:
                return
            self.scheduler.begin_drain(worker_id, notice_s)
            if router is not None:
                self.handoff_replicas(worker_id, router, weights=weights)

            def poll():
                if worker_id not in self.scheduler.workers:
                    return
                self.scheduler.check_drains(self.now)
                if self.scheduler.drain_complete(worker_id) \
                        and self.scheduler.finish_drain(worker_id):
                    self.release_workers([worker_id])
                    return
                self._post(poll_every, poll)
            poll()

            def revoke():
                if worker_id in self.scheduler.workers:
                    self._dead.add(worker_id)
                    self.scheduler.on_worker_failed(worker_id,
                                                    reason="preempted")
            self._post(notice_s, revoke)
        self._post(max(0.0, t - self.now), start)

    def run_serve(self, router, arrivals: List[Tuple[float, Any]],
                  tick_every: float = 0.01, drain_s: float = 0.0,
                  on_tick: Optional[Callable[[float], None]] = None,
                  replica_autoscaler=None) -> List[Any]:
        """Open-loop serving driver: submit each request at its virtual
        arrival time, tick the router (one decode step per replica slot)
        every `tick_every` virtual seconds, and run until everything
        admitted has completed plus `drain_s` of idle tail. Requests the
        router sheds are re-submitted on the next tick (closed retry
        loop), so the returned list is every request, completed. Construct
        the router with ``clock=lambda: sim.now`` so its p99 window
        measures virtual time."""
        completed: List[Any] = []
        pending: List[Any] = []
        submitted = [0]

        def arrive(req):
            submitted[0] += 1
            if not router.submit(req):
                pending.append(req)

        for t, req in arrivals:
            self._post(max(0.0, t - self.now), lambda r=req: arrive(r))
        last_arrival = max((t for t, _ in arrivals), default=self.now)
        done_since: List[Optional[float]] = [None]

        def settled() -> bool:
            return (self.now >= last_arrival
                    and submitted[0] >= len(arrivals)
                    and not pending and router.idle())

        def monitor():
            for req in pending[:]:
                if router.submit(req):
                    pending.remove(req)
            completed.extend(router.tick())
            if replica_autoscaler is not None:
                replica_autoscaler.tick(self.now)
            if self.autoscaler is not None:
                self.autoscaler.tick(self.now)
            if on_tick is not None:
                on_tick(self.now)
            if settled():
                if done_since[0] is None:
                    done_since[0] = self.now
                if self.now - done_since[0] >= drain_s:
                    return
            else:
                done_since[0] = None
            self._post(tick_every, monitor)

        self._post(tick_every, monitor)
        self.run()
        return completed

    # -- observability ----------------------------------------------------------------

    def export_metrics(self, router=None) -> Dict[str, Any]:
        """The head's `metrics`-op reply, sim-side: the SAME builder the
        threaded HeadServer uses over this cluster's real store and
        scheduler -- so every sim chaos scenario can end with the
        metrics-vs-reality conformance check. An attached router
        contributes the serving gauges exactly like stats_sink would."""
        serve = router.snapshot() if router is not None else None
        return build_cluster_metrics(self.store, self.scheduler,
                                     serve_stats=serve,
                                     replica_count=(len(self.replicas)
                                                    or None))

    def export_prometheus(self, router=None) -> str:
        """Prometheus text exposition of `export_metrics` plus the
        scheduler registry's histogram families."""
        return render_prometheus(self.scheduler.metrics,
                                 flat=self.export_metrics(router=router))

    def export_dashboards(self) -> Dict[str, Any]:
        return render_dashboards()

    # -- submission --------------------------------------------------------------------

    def submit(self, spec: TaskSpec, deps=None) -> Task:
        return self.scheduler.submit(spec, deps)

    # -- the cost model in action ---------------------------------------------------------

    def _fetch_deps(self, task: Task, worker_id: str, start: float) -> float:
        """Model dependency transfers onto `worker_id`; returns when the
        last dep lands. "p2p": each move serializes the two endpoints'
        NICs only (transfers between disjoint pairs overlap). "relay":
        every move serializes on the head's single link -- one hop when
        the head already holds the blob, two (worker->head->worker) when
        it must relay a worker-resident primary. The blob is also really
        copied through the store, so directory locality, link-load
        accounting and the planners see the same world the timing does."""
        done = start
        for d in task.deps:
            locs = self.store.locations(d)
            if worker_id in locs or not locs:
                continue
            size = self.store.size_of(d)
            relayed = 0
            if self.cost.data_plane == "p2p":
                src = self.store.choose_source(d, worker_id)
                if src is None:
                    continue
                # each endpoint's NIC serializes its own byte stream
                # (fair-shared links, coflow-style): the transfer is done
                # when the slower of the two has pushed/pulled the bytes
                dt = self.cost.link_latency_s \
                    + size / self.cost.node_bandwidth_Bps
                t_src = max(self._nic_free.get(src, 0.0), start) + dt
                t_dst = max(self._nic_free.get(worker_id, 0.0), start) + dt
                self._nic_free[src] = t_src
                self._nic_free[worker_id] = t_dst
                t1 = max(t_src, t_dst)
            else:                       # relay: the head's NIC is the bus
                src = "head" if "head" in locs else min(locs)
                hops = 1 if src == "head" else 2
                t0 = max(self._head_link_free, start)
                t1 = t0 + hops * (self.cost.link_latency_s
                                  + size / self.cost.head_bandwidth_Bps)
                self._head_link_free = t1
                if src != "head":
                    # worker-resident blob relayed through the head: the
                    # store only counts head-sourced bytes by itself
                    relayed = size
            try:
                self.store.fetch(worker_id, d, src=src)
            except KeyError:
                continue               # copy vanished mid-model: dep is lost
            if relayed:
                # charged only after the fetch lands: a copy that vanished
                # mid-model must not bill phantom bytes to the head NIC
                self.store.stats["head_relayed_bytes"] += relayed
            done = max(done, t1)
        return done

    def _launch(self, task: Task, worker_id: str):
        # serialized head dispatch
        self._head_dispatch_free = max(self._head_dispatch_free, self.now) \
            + self.cost.dispatch_overhead_s
        start = self._head_dispatch_free
        if self.cost.data_plane is not None and task.deps:
            start = self._fetch_deps(task, worker_id, start)
        speed = self._worker_speed.get(worker_id, 1.0)
        base = self.cost.task_time_s(task.spec) / max(speed, 1e-9)
        noise = 1.0 + self.cost.jitter * (self.rng.random() * 2 - 1)
        duration = base * noise
        finish = start + duration

        def complete():
            if worker_id in self._dead:
                return
            cur = self.scheduler.graph.tasks.get(task.id)
            if cur is None or cur.state != TaskState.RUNNING or cur.worker != worker_id:
                return
            if self.cost.data_plane == "p2p" \
                    and self.cost.result_location == "worker" \
                    and self.store.has_node(worker_id):
                # decentralized result: a local store write -- only the
                # metadata record crosses the head, not the payload
                done_at = self.now + self.cost.link_latency_s
            else:
                # result artifact flows through the head's serialized link
                xfer = self.cost.result_bytes(task.spec) \
                    / self.cost.head_bandwidth_Bps
                self._head_link_free = max(self._head_link_free,
                                           self.now) + xfer
                done_at = self._head_link_free

            def deliver():
                cur2 = self.scheduler.graph.tasks.get(task.id)
                if cur2 is None or cur2.state != TaskState.RUNNING:
                    return
                # "worker": the producer's node store owns the primary copy
                # (Ray-faithful -- this is what a drain must migrate);
                # "head": seed behavior, artifacts land on the head store
                node = worker_id if (self.cost.result_location == "worker"
                                     and self.store.has_node(worker_id)) \
                    else "head"
                payload = {"task": task.id,
                           "bytes": int(self.cost.result_bytes(task.spec))}
                # deterministic output id: a reconstructed producer revives
                # the same object id, waking tasks that waited on it; the
                # artifact is owned (and billed to) the task's tenant.
                # The payload is a token; the directory accounts the
                # *modeled* artifact size, so dep-transfer timing, quotas
                # and the drain planner all see the fat object
                ref = self.store.put(node, payload, producer_task=task.id,
                                     ref_id=f"obj-{task.id}",
                                     tenant=task.spec.tenant_id,
                                     size_hint=payload["bytes"])
                self.scheduler.on_task_finished(task.id, ref)
                self.completed.append(cur2)
            self._post(done_at - self.now, deliver)
        self._post(finish - self.now, complete)

    # -- convenience ----------------------------------------------------------------------

    def run_wave(self, specs: List[TaskSpec],
                 monitor_every: float = 0.05) -> float:
        """Submit a batch, run to completion, return makespan (virtual s).

        A periodic monitor event drives straggler checks while work is in
        flight (the head's health loop in the threaded backend)."""
        t0 = self.now
        ids = [self.submit(s).id for s in specs]

        def in_flight() -> bool:
            states = {self.scheduler.graph.tasks[i].state for i in ids}
            return not states <= {TaskState.FINISHED, TaskState.FAILED,
                                  TaskState.CANCELLED}

        def monitor():
            if not in_flight():
                return
            self.scheduler.check_stragglers()
            self.scheduler.check_drains(self.now)
            if self.autoscaler is not None:
                self.autoscaler.tick(self.now)
            self._post(monitor_every, monitor)

        self._post(monitor_every, monitor)
        guard = 0
        while True:
            self.run()
            if not in_flight():
                break
            self.scheduler.check_stragglers()
            self._post(monitor_every, monitor)
            guard += 1
            if guard > 10000:
                raise RuntimeError("simulation did not converge")
        return self.now - t0

    def run_scenario(self, arrivals: List[Tuple[float, TaskSpec]],
                     tick_every: float = 0.1,
                     drain_s: float = 0.0,
                     on_tick: Optional[Callable[[float], None]] = None
                     ) -> List[str]:
        """Timed-arrival driver for elastic workloads: submit each spec at
        its virtual arrival time, tick stragglers + autoscaler periodically,
        and run until every arrived task is terminal plus `drain_s` of idle
        tail (so idle scale-down gets a chance to fire). Returns task ids.
        `on_tick(now)` is called at every monitor tick (fairness sampling)."""
        ids: List[str] = []
        for t, spec in arrivals:
            self._post(max(0.0, t - self.now),
                       lambda s=spec: ids.append(self.submit(s).id))
        last_arrival = max((t for t, _ in arrivals), default=self.now)
        done_since: List[Optional[float]] = [None]
        terminal = {TaskState.FINISHED, TaskState.FAILED, TaskState.CANCELLED}

        def settled() -> bool:
            if self.now < last_arrival or len(ids) < len(arrivals):
                return False
            return {self.scheduler.graph.tasks[i].state
                    for i in ids} <= terminal

        def monitor():
            self.scheduler.check_stragglers()
            self.scheduler.check_drains(self.now)
            if self.autoscaler is not None:
                self.autoscaler.tick(self.now)
            if on_tick is not None:
                on_tick(self.now)
            if settled():
                if done_since[0] is None:
                    done_since[0] = self.now
                if self.now - done_since[0] >= drain_s:
                    return               # stop re-posting: loop drains out
            else:
                done_since[0] = None
            self._post(tick_every, monitor)

        self._post(tick_every, monitor)
        self.run()
        return ids

    def run_tenant_scenario(
            self, streams: Dict[str, List[Tuple[float, TaskSpec]]],
            tick_every: float = 0.1, drain_s: float = 0.0,
            on_tick: Optional[Callable[[float], None]] = None
    ) -> Dict[str, List[Tuple[float, str]]]:
        """Multi-tenant contention driver: each tenant brings its own timed
        arrival stream; specs are stamped with the tenant id and the merged
        stream runs under `run_scenario`. Returns, per tenant, the
        (arrival_time, task_id) pairs -- virtual-time sojourns fall out as
        `task.finished_at - arrival_time` (the fairness benchmark's input).
        """
        merged: List[Tuple[float, TaskSpec]] = []
        order: List[Tuple[str, float]] = []
        for tenant_id, arrivals in streams.items():
            self.scheduler._tenant_state(tenant_id)   # register, keep weight
            for t, spec in arrivals:
                spec.tenant_id = tenant_id
                merged.append((t, spec))
                order.append((tenant_id, t))
        # stable sort keeps per-tenant arrival order for equal timestamps;
        # run_scenario posts submissions in list order, so ids align
        idx = sorted(range(len(merged)), key=lambda i: merged[i][0])
        merged = [merged[i] for i in idx]
        order = [order[i] for i in idx]
        ids = self.run_scenario(merged, tick_every=tick_every,
                                drain_s=drain_s, on_tick=on_tick)
        out: Dict[str, List[Tuple[float, str]]] = {t: [] for t in streams}
        for (tenant_id, t), tid in zip(order, ids):
            out[tenant_id].append((t, tid))
        return out
