"""known-good: the envelope is opened (HMAC + nonce) before use."""
import json

from repro.core.security import open_sealed


class BlobIngest:
    def __init__(self, store, token, nonces):
        self.store = store
        self.token = token
        self.nonces = nonces

    def handle(self, sock):
        raw = json.loads(sock.recv(4096).decode())
        header = open_sealed(self.token, raw, nonce_cache=self.nonces)
        self.store.put_blob(header["object"], header["data"])
