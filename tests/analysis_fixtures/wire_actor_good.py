"""known-good: actor-directive sub-ops (queued AND inline under the poll
reply's ``actor_ops`` key) line up with the handler set -- the repaired
twin of wire_actor_bad.py."""


class Server:
    def __init__(self):
        self.actors = {}

    def dispatch(self, msg):
        op = msg.get("op")
        if op == "actor_create":
            self.actors[msg["actor"]] = msg["factory"]
            return {"ok": True, "actor": msg["actor"]}
        if op == "actor_call":
            value = self.actors[msg["actor"]](msg["payload"])
            return {"ok": True, "value": value}
        if op == "actor_exit":
            self.actors.pop(msg["actor"], None)
            return {"ok": True}
        return {"ok": False, "error": f"bad op {op}"}


def head_poll_reply(outbox):
    outbox.append({"op": "actor_create", "actor": "a", "factory": "F"})
    outbox.append({"op": "actor_call", "actor": "a", "payload": {}})
    return {"ok": True,
            "actor_ops": outbox + [{"op": "actor_exit", "actor": "a"}]}
