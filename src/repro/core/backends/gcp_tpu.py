"""Cloud-TPU backend: queued-resources allocation of TPU pod slices and a
per-host launch of the Syndeo worker + jax.distributed bootstrap.

This is the TPU adaptation of the paper's cloud path: the *outer* scheduler
is Cloud TPU's queued-resource manager (or GKE), the *inner* scheduler is
the Syndeo runtime, and within a training job XLA owns the chips (three
nested schedulers -- see DESIGN.md)."""
from __future__ import annotations

import re
from typing import Dict, List

from repro.core.backends.base import AllocationRequest, Backend


def _join_ordinal(worker_id: str) -> int:
    """Pod-slice join ordinal (trailing integer of the resource name);
    ids without one sort first (oldest)."""
    m = re.search(r"(\d+)$", worker_id)
    return int(m.group(1)) if m else -1


class GcpTpuBackend(Backend):
    name = "gcp_tpu"
    supports_elastic = True

    def render_artifacts(self, req: AllocationRequest,
                         cluster_id: str) -> Dict[str, str]:
        topo = req.tpu_topology or "16x16"
        create = f"""\
#!/bin/bash
set -euo pipefail
# outer scheduler: allocate the pod slices (gang allocation)
for POD in $(seq 0 {max(req.nodes - 1, 0)}); do
  gcloud compute tpus queued-resources create syndeo-{cluster_id}-$POD \\
    --node-id syndeo-{cluster_id}-$POD \\
    --accelerator-type v5litepod-256 \\
    --runtime-version v2-alpha-tpuv5-lite \\
    --zone us-central1-a &
done
wait
"""
        launch = f"""\
#!/bin/bash
set -euo pipefail
# middle scheduler: start the Syndeo head on pod 0 host 0, workers on all
# hosts; rendezvous via the GCS bucket (the cloud 'shared location').
RDV=gs://syndeo-rdv/{cluster_id}
for POD in $(seq 0 {max(req.nodes - 1, 0)}); do
  gcloud compute tpus tpu-vm ssh syndeo-{cluster_id}-$POD --worker=all \\
    --zone us-central1-a --command "
      docker run --privileged=false --net=host --user 1000:1000 \\
        {self.container.image.replace('.sif', ':latest')} \\
        python -m repro.core.worker \\
          --role \\$( [ $POD -eq 0 ] && echo head || echo worker ) \\
          --rendezvous $RDV --cluster-id {cluster_id} \\
          --jax-coordinator \\${{POD}}:8476 --mesh {topo}
    " &
done
wait
"""
        return {f"allocate_{cluster_id}.sh": create,
                f"launch_{cluster_id}.sh": launch}

    # -- elasticity: add/delete queued-resource pod slices ---------------------

    def provision_workers(self, req: AllocationRequest, cluster_id: str,
                          count: int) -> Dict[str, str]:
        image = self.container.image.replace('.sif', ':latest')
        script = f"""\
#!/bin/bash
set -euo pipefail
# elastic scale-up: allocate {count} more pod slices; each joins the live
# head as a worker via the GCS rendezvous (no head restart).
BASE=$(gcloud compute tpus queued-resources list \\
        --filter="name~syndeo-{cluster_id}" --format="value(name)" | wc -l)
for I in $(seq 0 {count - 1}); do
  POD=$((BASE + I))
  gcloud compute tpus queued-resources create syndeo-{cluster_id}-$POD \\
    --node-id syndeo-{cluster_id}-$POD \\
    --accelerator-type v5litepod-256 \\
    --runtime-version v2-alpha-tpuv5-lite \\
    --zone us-central1-a
  gcloud compute tpus tpu-vm ssh syndeo-{cluster_id}-$POD --worker=all \\
    --zone us-central1-a --command "
      docker run --privileged=false --net=host --user 1000:1000 \\
        {image} \\
        python -m repro.core.worker --role worker \\
          --rendezvous gs://syndeo-rdv/{cluster_id} --cluster-id {cluster_id} \\
          --blob-host \\$(hostname -i | cut -d' ' -f1)
    " &
done
wait
"""
        return {f"scale_up_{cluster_id}_{count}.sh": script}

    def release_workers(self, req: AllocationRequest, cluster_id: str,
                        worker_ids: List[str],
                        drain_deadline_s: float = 0.0) -> Dict[str, str]:
        # Reverse-join order: delete the most recently added slices first.
        # Pod 0 hosts the jax.distributed coordinator and early pods hold
        # the low ranks; releasing from the tail keeps coordinator ranks
        # stable so surviving slices never renumber mid-training.
        ordered = sorted(worker_ids, key=_join_ordinal, reverse=True)
        grace = (f"sleep {int(drain_deadline_s)}"
                 if drain_deadline_s > 0 else
                 ": # slices already drained by the inner scheduler")
        deletes = "\n".join(
            f"gcloud compute tpus queued-resources delete {wid} "
            f"--zone us-central1-a --force --quiet || true"
            for wid in ordered)
        script = f"""\
#!/bin/bash
set -euo pipefail
# graceful scale-down, reverse-join order (latest slices first): give any
# straggling host processes the drain grace, then return the pod slices
# to the outer scheduler (queued-resource manager).
{grace}
{deletes}
"""
        return {f"scale_down_{cluster_id}.sh": script}
