"""Serving example: continuous-batching engine on a small LM, driven as a
long-lived Syndeo actor-style job.

    PYTHONPATH=src python examples/serve.py
"""
import time

import jax

from repro.configs import get_config
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = get_config("llama3-8b", smoke=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, batch_slots=4, max_len=64)

    prompts = [[1, 5, 9], [2, 4], [7, 7, 7, 7], [3], [8, 1, 2], [9, 9]]
    reqs = [Request(id=i, prompt=p, max_new_tokens=8)
            for i, p in enumerate(prompts)]
    t0 = time.time()
    for r in reqs:
        engine.add_request(r)
    engine.run_until_drained()
    dt = time.time() - t0

    for r in reqs:
        print(f"req {r.id}: prompt={r.prompt} -> {r.output}")
    s = engine.stats
    print(f"\n{s['completed']} requests, {s['decoded_tokens']} tokens in "
          f"{dt:.2f}s ({s['decoded_tokens'] / dt:.1f} tok/s, "
          f"{s['ticks']} engine ticks, {s['prefills']} prefills)")
    assert all(r.done for r in reqs)


if __name__ == "__main__":
    main()
