"""Pallas TPU grouped (expert) matmul for MoE dispatch output.

Computes out[e] = x[e] @ w[e] for E experts with capacity-C token slots,
tiled so each (bc x bd) x (bd x bf) step is MXU-shaped and the fp32
accumulator lives in VMEM across the contraction dimension. The expert
dimension rides the grid -- weights stream from HBM once per (e, j) tile
column, tokens once per (e, i) row: exactly the blocking a production MoE
FFN uses on TPU.

Layout: x (E, C, d), w (E, d, f) -> out (E, C, f).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_db: int):
    l = pl.program_id(3)

    @pl.when(l == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0].astype(jnp.float32)          # (bc, bd)
    w = w_ref[0].astype(jnp.float32)          # (bd, bf)
    acc_ref[...] += jax.lax.dot_general(x, w, (((1,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32)

    @pl.when(l == n_db - 1)
    def _finish():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def moe_gmm(x: jax.Array, w: jax.Array, *, block_c: int = 128,
            block_d: int = 512, block_f: int = 256,
            interpret: bool = True) -> jax.Array:
    """x (E, C, d) @ w (E, d, f) -> (E, C, f)."""
    E, C, d = x.shape
    f = w.shape[2]
    block_c = min(block_c, C)
    block_d = min(block_d, d)
    block_f = min(block_f, f)
    assert C % block_c == 0 and d % block_d == 0 and f % block_f == 0
    n_db = d // block_d

    kernel = functools.partial(_gmm_kernel, n_db=n_db)
    return pl.pallas_call(
        kernel,
        grid=(E, C // block_c, f // block_f, n_db),
        in_specs=[
            pl.BlockSpec((1, block_c, block_d), lambda e, i, j, l: (e, i, l)),
            pl.BlockSpec((1, block_d, block_f), lambda e, i, j, l: (e, l, j)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda e, i, j, l: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, C, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        interpret=interpret,
    )(x, w)
