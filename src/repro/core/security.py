"""Security layer: the Apptainer principle, applied to the runtime.

The paper's security argument: containers run as *normal processes under
the user's account* -- no root daemon, administrators keep control. The
runtime equivalents implemented here:

  * UnprivilegedProfile -- refuses to run the cluster as root (mirroring
    Apptainer's no-root-daemon design), enforces a restrictive umask and
    an allowlisted scratch directory.
  * Cluster token + HMAC-signed message envelopes -- every head<->worker
    RPC is authenticated with a token minted at rendezvous; a node that
    does not hold the token cannot join or inject work (multi-tenant
    safety on a shared fabric). Envelopes carry an authenticated
    timestamp *and* a per-message nonce: a receiver that keeps a
    (bounded) NonceCache rejects replays inside the freshness window,
    not just stale captures outside it.
  * Capability tokens -- object-store access grants scoped to an object id
    and a right ("get"/"put"/"migrate"), signed with the cluster key.
  * Tenant principals -- per-tenant keys are *derived* from the cluster
    token (HMAC), so the head can hand each tenant a key that mints
    capabilities only for that tenant's objects. A capability carries its
    tenant id inside the MAC: tenant A's grant cannot be replayed against
    tenant B's objects, and the object store verifies the binding on
    every guarded get/put/migrate.
  * Transfer tickets -- short-lived capabilities for the peer-to-peer
    data plane. The head's poll reply names *where* a dependency lives
    (metadata only); the ticket authorizes the requesting worker to pull
    that one blob from that one source before the ticket expires. The MAC
    binds (object, source node, requesting worker, tenant, right, expiry),
    so a captured ticket cannot be relabeled for another object, replayed
    by another worker, pointed at another source, or presented after the
    fetch window closes. Three rights exist: "get" (pull), "put"
    (replication push, e.g. the leave handshake), and "migrate" -- the
    drain-move push right, minted only by the head when it PREPAREs a
    two-phase worker-to-worker move. A migrate ticket authorizes exactly
    one source worker to push exactly one object into exactly one
    destination's blob store; the destination's ack (not the ticket) is
    what commits the directory's owner handoff.
"""
from __future__ import annotations

import hashlib
import hmac
import json
import os
import secrets
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

#: capability scope that matches every tenant -- mintable only under the
#: cluster token itself (the head's drain/migration plane), never under a
#: derived tenant key.
ADMIN_TENANT = "*"

DEFAULT_TENANT = "default"


class SecurityError(RuntimeError):
    pass


def mint_cluster_token() -> str:
    return secrets.token_hex(32)


@dataclass(frozen=True)
class UnprivilegedProfile:
    """Execution profile every worker asserts before starting."""
    allow_root: bool = False
    umask: int = 0o077
    scratch_root: str = "/tmp"

    def enforce(self):
        if not self.allow_root and hasattr(os, "geteuid") and os.geteuid() == 0:
            # Multi-tenant HPC refuses root workers (Apptainer design). The
            # container CI runs as root, so tests construct the profile with
            # allow_root=True -- exactly the "single-tenant" relaxation the
            # paper describes for personal cloud instances.
            raise SecurityError(
                "refusing to start a worker as root: Syndeo workers run as "
                "normal user processes (see DESIGN.md / Apptainer security "
                "model); pass allow_root=True only on single-tenant nodes")
        os.umask(self.umask)

    def scratch_dir(self, cluster_id: str) -> str:
        path = os.path.join(self.scratch_root, f"syndeo-{cluster_id}")
        os.makedirs(path, mode=0o700, exist_ok=True)
        return path


class HybridClock:
    """Wall-anchored monotonic clock for expiry math.

    Envelope timestamps and ticket expiries must be *comparable across
    hosts* (so they are expressed as unix time), but the local math that
    decides "has this expired?" must not move when NTP steps the wall
    clock -- the store's move records and the scheduler's drain deadlines
    already use time.monotonic(), and a wall step that expires every
    in-flight ticket mid-transfer turns a clock adjustment into a storm
    of relay fallbacks. The hybrid clock anchors the wall time once at
    construction and advances it by the monotonic delta: the value stays
    unix-comparable on the wire while local progression is step-immune.
    """

    def __init__(self):
        self._wall0 = time.time()
        self._mono0 = time.monotonic()

    def now(self) -> float:
        return self._wall0 + (time.monotonic() - self._mono0)


#: process-wide clock used for seal timestamps, envelope freshness, and
#: ticket mint/verify defaults; swap with set_clock() in tests.
_clock = HybridClock()


def wall_now() -> float:
    """Current wall-anchored, monotonic-advancing unix time."""
    return _clock.now()


def set_clock(clock) -> Any:
    """Inject a clock (anything with .now() -> float); returns the old one."""
    global _clock
    prev = _clock
    _clock = clock
    return prev


def sign(token: str, payload: bytes) -> str:
    return hmac.new(token.encode(), payload, hashlib.sha256).hexdigest()


def tenant_key(cluster_token: str, tenant_id: str) -> str:
    """Per-tenant signing key, derived (not stored) from the cluster token.

    The head gives each tenant its derived key; the store re-derives it from
    the capability's tenant id at verification time, so no per-tenant state
    is needed on the verifying side."""
    if tenant_id == ADMIN_TENANT:
        raise SecurityError("the admin scope has no derivable tenant key")
    return sign(cluster_token, f"tenant-key:{tenant_id}".encode())


class NonceCache:
    """Bounded set of recently seen envelope nonces (replay rejection).

    FIFO-bounded: old nonces age out, which is safe because `open_sealed`
    also enforces the freshness window -- an envelope old enough for its
    nonce to have been evicted is already rejected as stale (choose
    `max_entries` >= the message rate times the freshness window)."""

    def __init__(self, max_entries: int = 65536):
        self.max_entries = max_entries
        self._seen: "OrderedDict[str, None]" = OrderedDict()
        # one cache is shared across handler threads (ThreadingTCPServer):
        # check+insert must be atomic or two concurrent replays both pass
        self._lock = threading.Lock()

    def __len__(self) -> int:
        with self._lock:
            return len(self._seen)

    def check_and_add(self, nonce: str):
        if not nonce:
            raise SecurityError("envelope without nonce rejected")
        with self._lock:
            if nonce in self._seen:
                raise SecurityError(
                    "replayed envelope rejected (duplicate nonce)")
            self._seen[nonce] = None
            while len(self._seen) > self.max_entries:
                self._seen.popitem(last=False)


def _envelope_bytes(msg: Dict[str, Any], ts: float, nonce: str) -> bytes:
    body = json.dumps(msg, sort_keys=True, default=repr).encode()
    # timestamp and nonce are authenticated too: a captured envelope cannot
    # be re-stamped fresh or re-nonced without breaking the MAC
    return f"{ts!r}|{nonce}|".encode() + body


def seal(token: str, msg: Dict[str, Any]) -> Dict[str, Any]:
    """Wrap a message in a signed envelope (MAC covers body + ts + nonce)."""
    ts = wall_now()
    nonce = secrets.token_hex(16)
    return {"body": msg, "ts": ts, "nonce": nonce,
            "mac": sign(token, _envelope_bytes(msg, ts, nonce))}


def open_sealed(token: str, envelope: Dict[str, Any],
                max_age_s: float = 3600.0,
                nonce_cache: Optional[NonceCache] = None) -> Dict[str, Any]:
    ts = envelope.get("ts", 0)
    nonce = envelope.get("nonce", "")
    mac = envelope.get("mac", "")
    want = sign(token, _envelope_bytes(envelope.get("body", {}), ts, nonce))
    if not hmac.compare_digest(mac, want):
        raise SecurityError("HMAC verification failed: message rejected")
    if wall_now() - ts > max_age_s:
        raise SecurityError("stale message rejected (replay window)")
    if nonce_cache is not None:
        # inside the freshness window, duplicates are replays: the nonce is
        # authenticated above, so an attacker cannot mint a fresh one
        nonce_cache.check_and_add(nonce)
    return envelope["body"]


@dataclass(frozen=True)
class Capability:
    object_id: str
    right: str          # "get" | "put" | "migrate"
    mac: str
    tenant_id: str = DEFAULT_TENANT

    @staticmethod
    def grant(token: str, object_id: str, right: str) -> "Capability":
        """Cluster-scoped (admin) grant, minted directly under the cluster
        token -- matches objects of every tenant. Only the head holds the
        cluster token, so only the head can mint these."""
        mac = sign(token, f"{object_id}:{right}".encode())
        return Capability(object_id, right, mac, tenant_id=ADMIN_TENANT)

    @staticmethod
    def grant_for_tenant(cluster_token: str, tenant_id: str,
                         object_id: str, right: str) -> "Capability":
        """Tenant-scoped grant: signed with the *derived* tenant key and
        carrying the tenant id inside the MAC, so it cannot be presented as
        another tenant's grant."""
        key = tenant_key(cluster_token, tenant_id)
        mac = sign(key, f"{tenant_id}:{object_id}:{right}".encode())
        return Capability(object_id, right, mac, tenant_id=tenant_id)

    @staticmethod
    def grant_actor(cluster_token: str, tenant_id: str,
                    actor_id: str) -> "Capability":
        """Actor-scoped grant for the serving plane: authorizes
        `actor_call`/`actor_exit` against exactly one live replica actor.
        The scope string ("actor:<id>") shares the object-capability MAC
        scheme, so an actor grant can never be replayed as a blob `get`
        (the right differs) or against another actor (the id is inside
        the MAC), and tenant derivation applies unchanged: tenant A's
        actor capability is useless against tenant B's replicas."""
        return Capability.grant_for_tenant(cluster_token, tenant_id,
                                           f"actor:{actor_id}", "call")

    def verify_actor(self, cluster_token: str, actor_id: str,
                     actor_tenant: str = DEFAULT_TENANT):
        """Head-side check before routing a call or exit to a replica."""
        self.verify(cluster_token, f"actor:{actor_id}", "call",
                    object_tenant=actor_tenant)

    def check(self, token: str, object_id: str, right: str):
        """Legacy cluster-scope check (MAC under the cluster token)."""
        want = sign(token, f"{object_id}:{right}".encode())
        if (self.object_id != object_id or self.right != right
                or not hmac.compare_digest(self.mac, want)):
            raise SecurityError(
                f"capability check failed for {right}:{object_id}")

    def verify(self, cluster_token: str, object_id: str, right: str,
               object_tenant: str = DEFAULT_TENANT):
        """Tenant-aware verification: the MAC must be valid for this
        capability's tenant scope AND the scope must cover the object's
        tenant. Admin capabilities (minted under the cluster token) cover
        every tenant; a tenant capability covers only its own."""
        if self.tenant_id == ADMIN_TENANT:
            self.check(cluster_token, object_id, right)
            return
        key = tenant_key(cluster_token, self.tenant_id)
        want = sign(key, f"{self.tenant_id}:{object_id}:{right}".encode())
        if (self.object_id != object_id or self.right != right
                or not hmac.compare_digest(self.mac, want)):
            raise SecurityError(
                f"capability check failed for {right}:{object_id} "
                f"(tenant {self.tenant_id})")
        if self.tenant_id != object_tenant:
            raise SecurityError(
                f"cross-tenant access denied: capability of tenant "
                f"{self.tenant_id!r} cannot {right} an object of tenant "
                f"{object_tenant!r}")


@dataclass(frozen=True)
class TransferTicket:
    """Short-lived grant for one peer-to-peer blob transfer.

    Minted by the head (the only directory authority) when it hands a
    worker the *locations* of a dependency instead of the bytes. The
    serving blob server re-verifies under the cluster token: every field
    below is inside the MAC, so none can be swapped after minting."""
    object_id: str
    src: str              # node that may serve the blob (push: the receiver)
    worker_id: str        # node allowed to pull it (push: the pusher)
    tenant_id: str        # tenant the blob belongs to (ADMIN_TENANT = any)
    right: str            # "get" (pull) | "put" (push) | "migrate" (drain move)
    expires_at: float     # unix time; the fetch window
    mac: str

    @staticmethod
    def _mac(token: str, object_id: str, src: str, worker_id: str,
             tenant_id: str, right: str, expires_at: float) -> str:
        return sign(token, f"xfer:{object_id}:{src}:{worker_id}:"
                           f"{tenant_id}:{right}:{expires_at!r}".encode())

    @staticmethod
    def grant(token: str, object_id: str, src: str, worker_id: str,
              tenant_id: str = DEFAULT_TENANT, right: str = "get",
              ttl_s: float = 30.0,
              now: Optional[float] = None) -> "TransferTicket":
        now = wall_now() if now is None else now
        exp = now + ttl_s
        return TransferTicket(
            object_id, src, worker_id, tenant_id, right, exp,
            TransferTicket._mac(token, object_id, src, worker_id,
                                tenant_id, right, exp))

    @staticmethod
    def grant_migrate(token: str, object_id: str, dst: str, src_worker: str,
                      tenant_id: str = DEFAULT_TENANT,
                      ttl_s: float = 60.0,
                      now: Optional[float] = None) -> "TransferTicket":
        """Drain-move push grant (the two-phase migrate protocol's PREPARE
        artifact): authorizes `src_worker` -- and only it -- to push
        `object_id` into `dst`'s blob store under the "migrate" right.
        The receiving blob server verifies it exactly like a put ticket
        but with right="migrate", so a replication put ticket cannot be
        replayed as a drain move (or vice versa)."""
        return TransferTicket.grant(token, object_id, dst, src_worker,
                                    tenant_id, "migrate", ttl_s=ttl_s,
                                    now=now)

    @staticmethod
    def grant_edge(token: str, object_id: str, src: str, dst: str,
                   tenant_id: str = DEFAULT_TENANT,
                   ttl_s: float = 30.0,
                   now: Optional[float] = None) -> "TransferTicket":
        """Broadcast-tree edge grant: authorizes `dst` to pull this one
        object from exactly `src` under the ordinary "get" right. The
        scoping is the point -- a consumer that landed a copy in round k
        of a broadcast serves round k+1's edges only through tickets the
        head minted for those exact (src, dst) pairs; relaying a copy
        never confers the right to serve arbitrary peers, and the ticket
        expires with the round's fetch window."""
        return TransferTicket.grant(token, object_id, src, dst,
                                    tenant_id, "get", ttl_s=ttl_s, now=now)

    def verify(self, token: str, object_id: str, src: str, worker_id: str,
               right: str = "get", object_tenant: str = DEFAULT_TENANT,
               now: Optional[float] = None):
        """Server-side check before any bytes move. Field mismatches and
        bad MACs are indistinguishable to the caller (one SecurityError),
        so a probing client learns nothing about which binding failed."""
        want = TransferTicket._mac(token, self.object_id, self.src,
                                   self.worker_id, self.tenant_id,
                                   self.right, self.expires_at)
        if (not hmac.compare_digest(self.mac, want)
                or self.object_id != object_id or self.src != src
                or self.worker_id != worker_id or self.right != right):
            raise SecurityError(
                f"transfer ticket rejected for {right}:{object_id} "
                f"({self.worker_id} <- {src})")
        now = wall_now() if now is None else now
        if now > self.expires_at:
            raise SecurityError(
                f"transfer ticket expired for {object_id} "
                f"({now - self.expires_at:.1f}s past the fetch window)")
        if self.tenant_id != ADMIN_TENANT and self.tenant_id != object_tenant:
            raise SecurityError(
                f"cross-tenant transfer denied: ticket of tenant "
                f"{self.tenant_id!r} cannot {right} an object of tenant "
                f"{object_tenant!r}")

    def to_wire(self) -> Dict[str, Any]:
        return {"object_id": self.object_id, "src": self.src,
                "worker_id": self.worker_id, "tenant_id": self.tenant_id,
                "right": self.right, "expires_at": self.expires_at,
                "mac": self.mac}

    @staticmethod
    def from_wire(d: Dict[str, Any]) -> "TransferTicket":
        return TransferTicket(
            str(d["object_id"]), str(d["src"]), str(d["worker_id"]),
            str(d["tenant_id"]), str(d.get("right", "get")),
            float(d["expires_at"]), str(d["mac"]))


@dataclass(frozen=True)
class Tenant:
    """A principal sharing the cluster: identity, fair-share weight, and the
    derived key it mints its own capabilities with (the tenant never sees
    the cluster token)."""
    tenant_id: str
    key: str = field(repr=False)
    weight: float = 1.0

    @staticmethod
    def derive(cluster_token: str, tenant_id: str,
               weight: float = 1.0) -> "Tenant":
        return Tenant(tenant_id, tenant_key(cluster_token, tenant_id), weight)

    def grant(self, object_id: str, right: str) -> Capability:
        """Mint a capability for one of *this tenant's* objects -- signed
        with the derived key, identical bytes to grant_for_tenant."""
        mac = sign(self.key, f"{self.tenant_id}:{object_id}:{right}".encode())
        return Capability(object_id, right, mac, tenant_id=self.tenant_id)
