"""In-process backends: the threaded `LocalBackend` (SyndeoCluster) and the
virtual-clock `SimBackend` (SimCluster) implement the elasticity hooks by
actually joining/retiring workers -- they *are* the cluster, so there are no
deployment artifacts to render beyond a manifest line.

These close the loop for the autoscaler: the same
`provision_workers`/`release_workers` interface that renders sbatch/kubectl/
gcloud artifacts for real resource managers executes directly here, which is
what the autoscaler tests and `benchmarks/autoscale_bench.py` drive.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.backends.base import AllocationRequest, Backend
from repro.core.cluster import ContainerSpec


class LocalBackend(Backend):
    """Threaded in-process workers (one python process == one container)."""

    name = "local"
    supports_elastic = True

    def __init__(self, container: ContainerSpec, cluster):
        super().__init__(container)
        self.cluster = cluster     # SyndeoCluster

    def render_artifacts(self, req: AllocationRequest,
                         cluster_id: str) -> Dict[str, str]:
        return {f"local_{cluster_id}.txt":
                f"in-process threaded cluster: nodes={req.nodes} "
                f"cpus_per_node={req.cpus_per_node}\n"}

    def provision_workers(self, req: AllocationRequest, cluster_id: str,
                          count: int) -> Dict[str, str]:
        for _ in range(count):
            self.cluster.add_worker(
                resources={"cpu": float(req.cpus_per_node)})
        return {}

    def release_workers(self, req: AllocationRequest, cluster_id: str,
                        worker_ids: List[str],
                        drain_deadline_s: float = 0.0) -> Dict[str, str]:
        for wid in worker_ids:
            # drain first (migrates solely-held hot objects to survivors);
            # fall back to the failure path only if the drain cannot finish
            if not self.cluster.drain_worker(
                    wid, deadline_s=drain_deadline_s or None,
                    timeout=max(drain_deadline_s, 2.0)):
                self.cluster.remove_worker(wid)
        return {}

    def preempt_workers(self, req: AllocationRequest, cluster_id: str,
                        worker_ids: List[str],
                        notice_s: float = 30.0) -> Dict[str, str]:
        # the notice window IS the drain budget: a worker that drains in
        # time exits cleanly; one that cannot goes through the failure
        # path when the window closes (the RM revokes the node anyway)
        for wid in worker_ids:
            if not self.cluster.drain_worker(wid, deadline_s=notice_s,
                                             timeout=notice_s):
                self.cluster.remove_worker(wid)
        return {}


class SimBackend(Backend):
    """Discrete-event workers joining after a provisioning delay."""

    name = "sim"
    supports_elastic = True

    def __init__(self, container: ContainerSpec, sim,
                 provision_delay_s: float = 1.0):
        super().__init__(container)
        self.sim = sim             # SimCluster
        self.provision_delay_s = provision_delay_s

    def render_artifacts(self, req: AllocationRequest,
                         cluster_id: str) -> Dict[str, str]:
        return {f"sim_{cluster_id}.txt":
                f"virtual-clock cluster: nodes={req.nodes} "
                f"provision_delay_s={self.provision_delay_s}\n"}

    def provision_workers(self, req: AllocationRequest, cluster_id: str,
                          count: int) -> Dict[str, str]:
        self.sim.provision_workers(count,
                                   cpus_per_worker=float(req.cpus_per_node),
                                   delay_s=self.provision_delay_s)
        return {}

    def release_workers(self, req: AllocationRequest, cluster_id: str,
                        worker_ids: List[str],
                        drain_deadline_s: float = 0.0) -> Dict[str, str]:
        # schedule a graceful drain (migration + release) in virtual time
        # for workers still registered; already-released ids just clean up
        for wid in worker_ids:
            if wid in self.sim.scheduler.workers:
                self.sim.drain_worker_at(wid, self.sim.now,
                                         deadline_s=drain_deadline_s or None)
            else:
                self.sim.release_workers([wid])
        return {}

    def preempt_workers(self, req: AllocationRequest, cluster_id: str,
                        worker_ids: List[str],
                        notice_s: float = 30.0) -> Dict[str, str]:
        # virtual-time preemption: begin_drain now, hard revoke at
        # now + notice_s if the drain plane has not finished by then
        for wid in worker_ids:
            if wid in self.sim.scheduler.workers:
                self.sim.preempt_worker_at(wid, self.sim.now, notice_s)
        return {}
